//! The serving engine: continuous-batched decode over the AOT-compiled
//! PJRT graphs with quantized KV-cache management -- the L3 realization
//! of the paper's Fig. 6 dataflow on the tiny shipped model.
//!
//! Numerics run on the CPU PJRT client; the *modeled* NPU-PIM timing
//! for the same step comes from the `accel` cost model, so the engine
//! reports both wall-clock (this host) and simulated-hardware numbers.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::Batcher;
use super::kvcache::{KvLayout, KvPool};
use super::request::{Request, RequestId, State};
use crate::config::llm::{LlmConfig, TINY};
use crate::runtime::artifacts::{lit_f32, lit_i32, vec_f32, Runtime};
use crate::runtime::weights::Weights;

pub const PREFILL_T: usize = 64;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub quantized: bool,
    pub max_batch: usize,
    /// KV pool capacity in packed bytes
    pub kv_capacity: usize,
    /// use persistent device buffers for weights (perf fast path)
    pub device_weights: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            quantized: true,
            max_batch: 8,
            kv_capacity: 64 << 20,
            // §Perf: persistent device-resident weight buffers cut the
            // decode step ~2.8x vs re-uploading literals every call
            device_weights: true,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct Stats {
    pub completed: usize,
    pub decode_steps: usize,
    pub tokens_out: usize,
    pub wall_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub ttft_ms: Vec<f64>,
    pub per_token_ms: Vec<f64>,
}

impl Stats {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_out as f64 / (self.decode_ms / 1e3).max(1e-9)
    }
    pub fn mean_ttft_ms(&self) -> f64 {
        if self.ttft_ms.is_empty() {
            return 0.0;
        }
        self.ttft_ms.iter().sum::<f64>() / self.ttft_ms.len() as f64
    }
}

pub struct Engine {
    pub rt: Runtime,
    pub model: LlmConfig,
    pub cfg: EngineConfig,
    pub weights: Weights,
    weight_lits: Vec<xla::Literal>,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pool: KvPool,
    batcher: Batcher,
    requests: HashMap<u64, Request>,
    next_id: u64,
    pub stats: Stats,
}

impl Engine {
    pub fn new(artifacts_dir: &str, cfg: EngineConfig) -> Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        let model = TINY.clone();
        let variant = if cfg.quantized { "bitmod" } else { "fp" };
        let weights = Weights::load(
            rt.artifacts.data_path(&format!("weights_{variant}"))?,
            &rt.artifacts.dir.join("weights.tsv"),
        )
        .context("loading weights")?;
        let mut weight_lits = vec![];
        for t in &weights.tensors {
            weight_lits.push(lit_f32(&t.dims, &t.f32_data)?);
        }
        let mut weight_bufs = vec![];
        if cfg.device_weights {
            for l in &weight_lits {
                weight_bufs.push(rt.to_device(l)?);
            }
        }
        let layout = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: model.max_ctx,
        };
        let pool = KvPool::new(layout, cfg.kv_capacity);
        let batcher = Batcher::new(cfg.max_batch);
        Ok(Engine {
            rt,
            model,
            cfg,
            weights,
            weight_lits,
            weight_bufs,
            pool,
            batcher,
            requests: HashMap::new(),
            next_id: 1,
            stats: Stats::default(),
        })
    }

    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, prompt, max_new);
        let rid = req.id;
        self.requests.insert(id, req);
        self.batcher.enqueue(rid);
        rid
    }

    pub fn request(&self, id: RequestId) -> Option<&Request> {
        self.requests.get(&id.0)
    }

    fn clone_weight_args(&self) -> Result<Vec<xla::Literal>> {
        self.weight_lits
            .iter()
            .map(crate::runtime::eval::clone_literal)
            .collect()
    }

    /// Prefill one request: run the prefill graph, quantize the prompt
    /// KV into the pool, emit the first token.
    fn prefill(&mut self, rid: RequestId) -> Result<()> {
        let t0 = Instant::now();
        let graph = if self.cfg.quantized { "prefill_q" } else { "prefill_fp" };
        let exe = self.rt.load(graph)?;
        let model = self.model.clone();
        let kvd = model.kv_dim();
        let req = self.requests.get_mut(&rid.0).ok_or_else(|| anyhow!("no req"))?;
        req.state = State::Prefilling;
        let true_len = req.prompt.len().min(PREFILL_T);
        let mut toks = vec![0i32; PREFILL_T];
        toks[..true_len].copy_from_slice(&req.prompt[..true_len]);

        let out = if self.cfg.device_weights {
            let dyn_lits = [
                lit_i32(&[1, PREFILL_T], &toks)?,
                lit_i32(&[], &[true_len as i32])?,
            ];
            let dyn_bufs: Vec<xla::PjRtBuffer> = dyn_lits
                .iter()
                .map(|l| self.rt.to_device(l))
                .collect::<Result<_>>()?;
            let mut refs: Vec<&xla::PjRtBuffer> =
                self.weight_bufs.iter().collect();
            refs.extend(dyn_bufs.iter());
            exe.run_b(&refs)?
        } else {
            let mut args = self.clone_weight_args()?;
            args.push(lit_i32(&[1, PREFILL_T], &toks)?);
            args.push(lit_i32(&[], &[true_len as i32])?);
            exe.run(&args)?
        };
        let logits = vec_f32(&out[0])?;
        let kc = vec_f32(&out[1])?; // [L,1,T,kvd]
        let vc = vec_f32(&out[2])?;
        let sf = vec_f32(&out[3])?; // [L,kvd]

        let smooth: Vec<Vec<f32>> = (0..model.layers)
            .map(|l| {
                if self.cfg.quantized {
                    sf[l * kvd..(l + 1) * kvd].to_vec()
                } else {
                    vec![1.0; kvd]
                }
            })
            .collect();
        let entry = self.pool.alloc(rid.0, smooth)?;
        for t in 0..true_len {
            for l in 0..model.layers {
                let off = (l * PREFILL_T + t) * kvd;
                entry.push_token(l, &kc[off..off + kvd], &vc[off..off + kvd]);
            }
            entry.commit_token();
        }
        let req = self.requests.get_mut(&rid.0).unwrap();
        req.pos = true_len;
        let next = argmax(&logits);
        req.generated.push(next);
        req.pos += 1; // KV slot for `next` is written by the first decode
        req.first_token = Some(Instant::now());
        req.state = State::Decoding;
        self.stats.prefill_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(())
    }

    /// One decode step over the active batch.  Returns tokens emitted.
    pub fn step(&mut self) -> Result<usize> {
        for rid in self.batcher.admit() {
            self.prefill(rid)?;
        }
        let Some(b) = self.batcher.graph_batch() else { return Ok(0) };
        let t0 = Instant::now();
        let model = self.model.clone();
        let (l, ctx, kvd) = (model.layers, model.max_ctx, model.kv_dim());
        let graph =
            if self.cfg.quantized { format!("decode_q_b{b}") } else { format!("decode_fp_b{b}") };
        let exe = self.rt.load(&graph)?;

        let active: Vec<RequestId> = self.batcher.active().to_vec();
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut kc = vec![0.0f32; l * b * ctx * kvd];
        let mut vc = vec![0.0f32; l * b * ctx * kvd];
        let mut sfb = vec![1.0f32; l * b * kvd];
        let mut kscratch = vec![0.0f32; ctx * kvd];
        let mut vscratch = vec![0.0f32; ctx * kvd];
        for (lane, rid) in active.iter().enumerate() {
            let req = &self.requests[&rid.0];
            tokens[lane] = req.last_token();
            pos[lane] = (req.pos - 1) as i32; // slot for the pending token
            let entry = self.pool.get(rid.0).ok_or_else(|| anyhow!("no kv"))?;
            for layer in 0..l {
                entry.dequant_layer(layer, &mut kscratch, &mut vscratch);
                let off = (layer * b + lane) * ctx * kvd;
                kc[off..off + ctx * kvd].copy_from_slice(&kscratch);
                vc[off..off + ctx * kvd].copy_from_slice(&vscratch);
                let soff = (layer * b + lane) * kvd;
                sfb[soff..soff + kvd].copy_from_slice(&entry.smooth[layer]);
            }
        }

        let out = if self.cfg.device_weights {
            let dyn_lits = [
                lit_i32(&[b], &tokens)?,
                lit_i32(&[b], &pos)?,
                lit_f32(&[l, b, ctx, kvd], &kc)?,
                lit_f32(&[l, b, ctx, kvd], &vc)?,
                lit_f32(&[l, b, kvd], &sfb)?,
            ];
            let dyn_bufs: Vec<xla::PjRtBuffer> = dyn_lits
                .iter()
                .map(|lit| self.rt.to_device(lit))
                .collect::<Result<_>>()?;
            let mut refs: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
            refs.extend(dyn_bufs.iter());
            exe.run_b(&refs)?
        } else {
            let mut args = self.clone_weight_args()?;
            args.push(lit_i32(&[b], &tokens)?);
            args.push(lit_i32(&[b], &pos)?);
            args.push(lit_f32(&[l, b, ctx, kvd], &kc)?);
            args.push(lit_f32(&[l, b, ctx, kvd], &vc)?);
            args.push(lit_f32(&[l, b, kvd], &sfb)?);
            exe.run(&args)?
        };
        let logits = vec_f32(&out[0])?; // [b, vocab]
        let new_k = vec_f32(&out[1])?; // [l, b, kvd]
        let new_v = vec_f32(&out[2])?;

        let mut emitted = 0;
        for (lane, rid) in active.iter().enumerate() {
            // store the k/v of the token we just processed
            let entry = self.pool.get_mut(rid.0).unwrap();
            for layer in 0..l {
                let off = (layer * b + lane) * kvd;
                entry.push_token(layer, &new_k[off..off + kvd], &new_v[off..off + kvd]);
            }
            entry.commit_token();
            let req = self.requests.get_mut(&rid.0).unwrap();
            let next = argmax(&logits[lane * model.vocab..(lane + 1) * model.vocab]);
            req.generated.push(next);
            req.pos += 1;
            emitted += 1;
            if req.done(model.max_ctx) {
                req.state = State::Finished;
                req.finished = Some(Instant::now());
                if let Some(t) = req.ttft_ms() {
                    self.stats.ttft_ms.push(t);
                }
                self.stats.completed += 1;
                self.batcher.retire(*rid);
                self.pool.free(rid.0);
            }
        }
        self.stats.decode_steps += 1;
        self.stats.tokens_out += emitted;
        self.stats.decode_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(emitted)
    }

    /// Run until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Stats> {
        let t0 = Instant::now();
        let mut guard = 0usize;
        while !self.batcher.idle() {
            self.step()?;
            guard += 1;
            if guard > 100_000 {
                bail!("serve loop did not converge");
            }
        }
        self.stats.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(self.stats.clone())
    }

    pub fn pool_used_bytes(&self) -> usize {
        self.pool.used_bytes()
    }
}

pub fn argmax(xs: &[f32]) -> i32 {
    let mut bi = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    bi as i32
}

#[cfg(test)]
mod tests {
    #[test]
    fn argmax_basic() {
        assert_eq!(super::argmax(&[0.1, -2.0, 5.0, 3.0]), 2);
    }
}
