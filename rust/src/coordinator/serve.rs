//! The serving engine: continuous-batched decode with quantized
//! KV-cache management over a pluggable execution substrate -- the L3
//! realization of the paper's Fig. 6 dataflow.
//!
//! The engine owns the request lifecycle (submit -> prefill -> decode
//! -> retire), the [`Batcher`], the page-granular INT4-packed
//! [`KvPool`] (with shared-prefix caching: a prompt starting with an
//! already-served prefix adopts its cached pages and prefills only the
//! suffix) and the latency metrics; the numerics and the clock come
//! from an [`ExecBackend`]: real PJRT graphs (wall time) or the
//! NPU-PIM cost model (simulated time).  Construct engines with
//! [`EngineBuilder`]:
//!
//! ```
//! use p3llm::coordinator::EngineBuilder;
//! # fn main() -> p3llm::Result<()> {
//! let mut eng = EngineBuilder::sim()
//!     .model("tiny-1M")
//!     .scheme("p3llm")
//!     .max_batch(4)
//!     .ctx_limit(128)
//!     .build()?;
//! let id = eng.submit(vec![1, 2, 3], 8)?;
//! let metrics = eng.run_to_completion()?;
//! assert_eq!(metrics.completed, 1);
//! println!("p95 TTFT {:.1} ms", metrics.ttft_ms.p95);
//! # Ok(())
//! # }
//! ```

use std::collections::HashMap;

use super::backend::{BackendKind, ExecBackend, Lane, PrefillOut};
use super::batcher::{Batcher, COMPILED_BATCHES};
use super::kvcache::{KvLayout, KvPool, PrefixHit, PAGE_TOKENS};
use super::pjrt::PjrtBackend;
use super::request::{Request, RequestId, RequestStatus, State};
use super::simbackend::SimBackend;
use crate::config::accel::HbmTiming;
use crate::config::cxl::CxlLink;
use crate::config::llm::LlmConfig;
use crate::config::scheme;
use crate::mem::TieredKv;
use crate::coordinator::mapper::MapSummary;
use crate::error::{P3Error, Result};
use crate::obs::Obs;
use crate::sched::{SloClass, VictimCandidate, VictimMode, VictimPolicy};
use crate::telemetry::{Trace, TraceLane};

/// Latency distribution summary (nearest-rank percentiles).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Percentiles {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Percentiles {
    /// Nearest-rank percentiles over the finite samples.  Well-defined
    /// for every input: non-finite samples are dropped, an empty (or
    /// all-dropped) series yields the all-zero default with `count`
    /// 0, a single sample is every percentile of itself -- no NaN
    /// propagation, no panic.
    pub fn from_samples(samples: &[f64]) -> Self {
        let mut xs: Vec<f64> =
            samples.iter().copied().filter(|x| x.is_finite()).collect();
        if xs.is_empty() {
            return Percentiles::default();
        }
        xs.sort_by(|a, b| a.total_cmp(b));
        let n = xs.len();
        // nearest-rank in integer math: ceil(n * pct / 100), 1-indexed
        let rank = |pct: usize| xs[(n * pct).div_ceil(100).max(1) - 1];
        Percentiles {
            count: n,
            mean: xs.iter().sum::<f64>() / n as f64,
            p50: rank(50),
            p95: rank(95),
            p99: rank(99),
            max: xs[n - 1],
        }
    }

    /// Count-weighted merge of several summaries (fleet reporting:
    /// per-replica distributions into one).  The raw samples are gone,
    /// so each input is re-expanded into a weighted sample set at its
    /// own quantile points (50% of its samples at p50, the next 45% at
    /// p95, 4% at p99, 1% at max) and the merged percentiles are
    /// nearest-rank over that set.  Exact for a single input; for many
    /// inputs it is the standard summary-merge approximation.  Means
    /// merge exactly; `max` is the max of maxes.
    pub fn merge(parts: &[&Percentiles]) -> Percentiles {
        let total: usize = parts.iter().map(|p| p.count).sum();
        if total == 0 {
            return Percentiles::default();
        }
        let mut atoms: Vec<(f64, f64)> = Vec::with_capacity(4 * parts.len());
        let mut mean_sum = 0.0;
        for p in parts {
            if p.count == 0 {
                continue;
            }
            let n = p.count as f64;
            mean_sum += p.mean * n;
            atoms.push((p.p50, 0.50 * n));
            atoms.push((p.p95, 0.45 * n));
            atoms.push((p.p99, 0.04 * n));
            atoms.push((p.max, 0.01 * n));
        }
        atoms.sort_by(|a, b| a.0.total_cmp(&b.0));
        let total_w: f64 = atoms.iter().map(|a| a.1).sum();
        let rank = |pct: f64| {
            let target = total_w * pct / 100.0;
            let mut acc = 0.0;
            for &(v, w) in &atoms {
                acc += w;
                if acc + 1e-9 >= target {
                    return v;
                }
            }
            atoms[atoms.len() - 1].0
        };
        Percentiles {
            count: total,
            mean: mean_sum / total as f64,
            p50: rank(50.0),
            p95: rank(95.0),
            p99: rank(99.0),
            max: atoms[atoms.len() - 1].0,
        }
    }
}

/// End-of-run serving metrics.  Latency distributions replace the old
/// flat sample vectors: TTFT and per-token (TPOT) percentiles are what
/// the serving experiments compare across backends and systems.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// backend short name ("pjrt" wall-clock, "sim" modeled time)
    pub backend: &'static str,
    pub completed: usize,
    pub decode_steps: usize,
    /// decode-emitted tokens (the prefill-emitted first token of each
    /// request is excluded, matching the original accounting)
    pub tokens_out: usize,
    /// engine-clock age at measurement (simulated ms for sim)
    pub wall_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// requests whose prefill hit the shared-prefix KV cache
    pub prefix_hits: usize,
    /// prompt tokens whose prefill compute the cache skipped
    pub prefix_tokens_saved: usize,
    /// mid-decode evictions by the preemptive scheduler (0 without a
    /// victim policy)
    pub preemptions: usize,
    /// KV pages migrated to the modeled slow tier (swap victims)
    pub pages_swapped: usize,
    /// KV pages dropped for re-prefill (recompute victims)
    pub pages_recomputed: usize,
    /// KV pages the ahead-of-decode prefetcher pulled back from the
    /// CXL cold tier before the step that reads them -- overlapped
    /// with the previous step's compute, so no engine-clock charge
    /// (0 on single-tier engines)
    pub pages_prefetched: usize,
    /// cold-tier KV pages demand-migrated at step time, each charged
    /// as an engine-clock stall (0 on single-tier engines)
    pub pages_demand: usize,
    /// NPU busy time summed across both sub-batch timelines (ms; 0
    /// when the engine runs the serial schedule)
    pub npu_busy_ms: f64,
    /// PIM busy time summed across both sub-batch timelines (ms)
    pub pim_busy_ms: f64,
    /// wall time NPU and PIM ran concurrently (ms; raw sum so fleet
    /// reports merge by addition -- see [`Metrics::overlap_factor`])
    pub overlap_ms: f64,
    /// decode steps charged on the two-timeline critical path
    pub interleaved_steps: u64,
    /// decode steps where the split lost and the sub-batches fused
    /// back into one serial step
    pub fused_steps: u64,
    /// serial-schedule cost minus the charged critical path, summed
    /// over interleaved steps (ms saved vs `interleave=off`)
    pub serial_saved_ms: f64,
    pub ttft_ms: Percentiles,
    pub per_token_ms: Percentiles,
}

impl Metrics {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_out as f64 / (self.decode_ms / 1e3).max(1e-9)
    }

    pub fn mean_ttft_ms(&self) -> f64 {
        self.ttft_ms.mean
    }

    /// NPU‖PIM concurrency ratio in `[0, 1]`: overlap time over the
    /// scarcer engine's total busy time.  ~0 under the serial
    /// schedule; the interleave smoke gates on > 0.3.
    pub fn overlap_factor(&self) -> f64 {
        let floor = self.npu_busy_ms.min(self.pim_busy_ms);
        if floor > 0.0 {
            self.overlap_ms / floor
        } else {
            0.0
        }
    }
}

/// Internal per-run accumulator the public [`Metrics`] is derived from.
#[derive(Debug, Default, Clone)]
struct StatsAcc {
    completed: usize,
    decode_steps: usize,
    tokens_out: usize,
    prefill_ms: f64,
    decode_ms: f64,
    prefix_hits: usize,
    prefix_tokens_saved: usize,
    preemptions: usize,
    pages_swapped: usize,
    pages_recomputed: usize,
    pages_prefetched: usize,
    pages_demand: usize,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
}

/// Preemptive-scheduling state (present only when the builder selected
/// a victim policy; `None` keeps the engine strictly FIFO).
struct SchedState {
    victim: Box<dyn VictimPolicy>,
    /// anti-starvation floor: a request queued longer than this is
    /// promoted to top effective rank -- first in line for admission
    /// and no longer preemptible
    aging_ms: f64,
    /// HBM timing the swap transfer model prices against
    hbm: HbmTiming,
}

/// Two-tier KV hierarchy state (present only when the builder set a
/// hot-tier fraction; `None` keeps every page HBM-resident).
struct TierState {
    /// per-page hot/cold residency overlay with the ahead-of-decode
    /// prefetcher and LRU eviction to the hot cap
    tier: TieredKv,
    /// modeled cost of moving one KV page across the CXL link (ms),
    /// priced once at build ([`crate::mem::page_migration_ms`])
    page_ms: f64,
}

/// Nominal class rank, promoted to 0 once the request has waited past
/// the aging floor (measured from submission on the engine clock).
fn effective_rank(req: &Request, now_ms: f64, aging_ms: f64) -> u8 {
    if now_ms - req.submitted_ms >= aging_ms {
        0
    } else {
        req.class.rank()
    }
}

pub struct Engine {
    backend: Box<dyn ExecBackend>,
    model: LlmConfig,
    /// context cap for request completion (= KV pool layout max_ctx)
    ctx_cap: usize,
    pool: KvPool,
    /// shared-prefix KV caching (lookup at prefill, register after)
    prefix_cache: bool,
    batcher: Batcher,
    requests: HashMap<u64, Request>,
    next_id: u64,
    acc: StatsAcc,
    /// SLO-tiered preemptive scheduling (None = FIFO)
    sched: Option<SchedState>,
    /// HBM-hot / CXL-cold tiered KV hierarchy (None = single-tier)
    tier: Option<TierState>,
    /// NPU/PIM sub-batch interleaving: split each decode step's lanes
    /// into two sub-batches whose engine phases overlap (false = the
    /// serial schedule, bit-identical to the pre-interleave engine)
    interleave: bool,
    /// request-lifecycle telemetry (default off = zero overhead)
    trace: Trace,
    /// metrics registry + scraper + SLO burn-rate alerting (default
    /// off = zero overhead)
    obs: Obs,
}

impl Engine {
    /// Wrap an execution backend in the serving lifecycle.  `ctx_cap`
    /// bounds the longest admissible request (None = the model's max
    /// context); `prefix_cache` enables shared-prefix KV caching.
    /// Prefer [`EngineBuilder`].
    pub fn with_backend(
        backend: Box<dyn ExecBackend>,
        max_batch: usize,
        kv_capacity: usize,
        ctx_cap: Option<usize>,
        prefix_cache: bool,
    ) -> Result<Self> {
        let model = backend.model().clone();
        let ctx_cap = ctx_cap.unwrap_or(model.max_ctx).min(model.max_ctx);
        if ctx_cap < 2 {
            return Err(P3Error::InvalidConfig(
                "context cap must allow at least prompt + one token".into(),
            ));
        }
        if max_batch < 1 {
            return Err(P3Error::InvalidConfig("max_batch must be >= 1".into()));
        }
        let layout = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: ctx_cap,
        };
        let pool = KvPool::new(layout, kv_capacity);
        if pool.total_pages() < pool.layout.pages_per_request() {
            return Err(P3Error::InvalidConfig(format!(
                "kv_capacity {} bytes holds no full-context request (one \
                 can touch {} bytes = {} pages; lower the ctx limit or \
                 raise the capacity)",
                kv_capacity,
                pool.bytes_per_request(),
                pool.layout.pages_per_request()
            )));
        }
        Ok(Engine {
            backend,
            model,
            ctx_cap,
            pool,
            prefix_cache,
            batcher: Batcher::new(max_batch),
            requests: HashMap::new(),
            next_id: 1,
            acc: StatsAcc::default(),
            sched: None,
            tier: None,
            interleave: false,
            trace: Trace::off(),
            obs: Obs::off(),
        })
    }

    /// Adopt a telemetry handle: the engine records the request
    /// lifecycle (enqueue / admit / bounce / prefill / tokens /
    /// preempt / retire) and the backend records device-occupancy
    /// lanes, all on the engine clock.  The handle's replica tag
    /// stamps every event ([`Trace::for_replica`]); the default-off
    /// handle makes every emit a no-op.
    pub fn set_trace(&mut self, trace: Trace) {
        self.backend.set_trace(trace.clone());
        self.trace = trace;
    }

    /// The engine's telemetry handle (disabled unless
    /// [`set_trace`](Engine::set_trace) /
    /// [`EngineBuilder::telemetry`] installed one).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Adopt an observability handle: the engine feeds the metrics
    /// registry (admission / preemption / prefix-cache counters,
    /// queue-depth and KV-occupancy gauges, per-tier SLO miss counters
    /// + latency histograms) and drives its fixed-interval scraper +
    /// burn-rate alert evaluation on the engine clock.  The handle's
    /// replica tag stamps every sample ([`Obs::for_replica`]); the
    /// default-off handle makes every emit a no-op.
    pub fn set_obs(&mut self, obs: Obs) {
        self.obs = obs;
    }

    /// The engine's observability handle (disabled unless
    /// [`set_obs`](Engine::set_obs) / [`EngineBuilder::observe`]
    /// installed one).
    pub fn obs(&self) -> &Obs {
        &self.obs
    }

    pub fn model(&self) -> &LlmConfig {
        &self.model
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Engine clock (backend-defined: wall ms for PJRT, simulated ms
    /// for sim).  Request timestamps live on this clock.
    pub fn now_ms(&self) -> f64 {
        self.backend.now_ms()
    }

    /// No queued and no active requests.
    pub fn is_idle(&self) -> bool {
        self.batcher.idle()
    }

    /// Fast-forward the engine clock to absolute `ms` (closed-loop
    /// load generation jumps over idle gaps between arrivals).
    /// Wall-clock backends cannot fast-forward and ignore this.
    pub fn advance_clock_to(&mut self, ms: f64) {
        self.backend.advance_to(ms);
    }

    /// Longest admissible prompt for this engine.  Backends that
    /// support chunked prefill (sim) absorb any prompt the context can
    /// hold in `ceil(len / tile)` tiles; single-tile backends (PJRT)
    /// are limited to one prefill graph invocation.
    pub fn max_prompt(&self) -> usize {
        if self.backend.chunked_prefill() {
            self.ctx_cap - 1
        } else {
            self.backend.max_prefill().min(self.ctx_cap - 1)
        }
    }

    /// Submit a prompt; rejects empty and over-long prompts with typed
    /// errors instead of the old silent truncation.  On chunking
    /// backends, prompts longer than one prefill tile are absorbed in
    /// `ceil(len / tile)` chunks at prefill time.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<RequestId> {
        self.submit_inner(prompt, max_new, None, SloClass::Interactive)
    }

    /// [`Engine::submit`] with an explicit SLO priority tier.  The
    /// class drives admission ordering and victim selection when the
    /// engine has a preemptive scheduler; a FIFO engine carries it
    /// through to reporting unchanged.
    pub fn submit_class(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        class: SloClass,
    ) -> Result<RequestId> {
        self.submit_inner(prompt, max_new, None, class)
    }

    /// Submit a request whose prompt KV was prefilled on another
    /// engine and migrates in (prefill/decode disaggregation):
    /// installing the KV charges `install_ms` of modeled transfer time
    /// instead of prefill compute.  Wall-clock backends cannot absorb
    /// foreign KV and fall back to a real prefill.
    pub fn submit_prefilled(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        install_ms: f64,
    ) -> Result<RequestId> {
        self.submit_prefilled_class(
            prompt,
            max_new,
            install_ms,
            SloClass::Interactive,
        )
    }

    /// [`Engine::submit_prefilled`] with an explicit SLO priority tier
    /// (disaggregated clusters carry the class across the handoff).
    pub fn submit_prefilled_class(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        install_ms: f64,
        class: SloClass,
    ) -> Result<RequestId> {
        if !install_ms.is_finite() || install_ms < 0.0 {
            return Err(P3Error::InvalidConfig(format!(
                "KV install charge must be finite and >= 0 ms, got \
                 {install_ms}"
            )));
        }
        self.submit_inner(prompt, max_new, Some(install_ms), class)
    }

    fn submit_inner(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        install_ms: Option<f64>,
        class: SloClass,
    ) -> Result<RequestId> {
        if prompt.is_empty() {
            return Err(P3Error::EmptyPrompt);
        }
        let max = self.max_prompt();
        if prompt.len() > max {
            return Err(P3Error::PromptTooLong { len: prompt.len(), max });
        }
        let id = self.next_id;
        self.next_id += 1;
        let prompt_len = prompt.len();
        let mut req = Request::new(id, prompt, max_new, self.backend.now_ms());
        req.prefill_charge_ms = install_ms;
        req.class = class;
        let rid = req.id;
        self.requests.insert(id, req);
        self.batcher.enqueue(rid);
        self.trace.instant(
            "enqueue",
            self.backend.now_ms(),
            Some(rid.0),
            Some(class),
            prompt_len as f64,
        );
        self.obs.counter_add("submitted", Some(class), 1.0);
        Ok(rid)
    }

    /// Requests waiting for admission (not yet prefilling/decoding).
    pub fn queued(&self) -> usize {
        self.batcher.queued()
    }

    /// Requests currently holding a decode lane.
    pub fn active_lanes(&self) -> usize {
        self.batcher.active().len()
    }

    pub fn request(&self, id: RequestId) -> Option<&Request> {
        self.requests.get(&id.0)
    }

    /// Lifecycle snapshot of one request.
    pub fn poll(&self, id: RequestId) -> Result<RequestStatus> {
        self.requests
            .get(&id.0)
            .map(|r| r.status())
            .ok_or(P3Error::UnknownRequest(id.0))
    }

    /// Drain tokens generated since the last drain (streaming).
    pub fn take_tokens(&mut self, id: RequestId) -> Result<Vec<i32>> {
        self.requests
            .get_mut(&id.0)
            .map(|r| r.take_new_tokens())
            .ok_or(P3Error::UnknownRequest(id.0))
    }

    /// Prefill one admitted request: look up the shared-prefix cache,
    /// run the backend prefill over the *suffix* (in `ceil(len /
    /// tile)` chunks on chunking backends -- a hit skips the cached
    /// span's compute entirely; the sim backend's incremental tile
    /// costing charges only `prefill_ms(total) - prefill_ms(cached)`),
    /// install the prompt KV in the pool, register the prompt's full
    /// pages for future hits, and emit the first token.  Requests
    /// arriving with a migrated KV (`submit_prefilled`) install it at
    /// the recorded transfer charge instead and bypass the cache (the
    /// charge already prices the whole prompt).
    fn prefill(&mut self, rid: RequestId) -> Result<()> {
        let t0 = self.backend.now_ms();
        let req = self
            .requests
            .get_mut(&rid.0)
            .ok_or(P3Error::UnknownRequest(rid.0))?;
        req.state = State::Prefilling;
        // queueing delay measures time to FIRST service: a preempted
        // request coming back keeps its original prefill start
        if req.prefill_start_ms.is_none() {
            req.prefill_start_ms = Some(t0);
        }
        // a resuming victim (preempted mid-decode) re-installs its
        // whole context -- prompt plus every generated token except
        // the pending one (whose KV the next decode step writes)
        let resume = !req.generated.is_empty();
        let ctx: Vec<i32> = if resume {
            let g = req.generated.len();
            req.prompt
                .iter()
                .chain(req.generated[..g - 1].iter())
                .copied()
                .collect()
        } else {
            req.prompt.clone()
        };
        let prompt_len = req.prompt.len();
        let max_new = req.max_new_tokens;
        let charge = req.prefill_charge_ms;
        let class = req.class;
        let use_cache = self.prefix_cache && charge.is_none();
        // the lookup pins the matched pages (they cannot be evicted
        // while the backend runs); the hit is resolved below -- by
        // alloc_seq on success, or released on a backend error.  On a
        // recompute resume this is what makes eviction cheap: the
        // victim's own registered prompt pages are still cached, so
        // only the generated suffix re-prefills.
        let hit = if use_cache {
            self.pool.lookup_prefix(&ctx)
        } else {
            None
        };
        let cached = hit.as_ref().map(|h| h.tokens).unwrap_or(0);
        let total_max = (prompt_len + max_new).min(self.ctx_cap);
        // tiles STREAM into the pool: each backend output is packed to
        // INT4 pages and dropped before the next tile runs, so a long
        // prompt never holds its full float K/V at once -- peak
        // transient memory is one tile, which is what makes the
        // 32k-128k long-context scenarios servable
        let mut hit = hit;
        let mut installed = false;
        let mut total_len = cached;
        let mut first_token = 0i32;
        let mut backend_err: Option<P3Error> = None;
        match charge {
            Some(ms) => match self.backend.install_prefill(&ctx, ms) {
                Ok(o) => {
                    let (n, ft) = self.install_tile(
                        rid, total_max, o, &mut hit, &mut installed,
                    )?;
                    total_len += n;
                    first_token = ft;
                }
                Err(e) => backend_err = Some(e),
            },
            None => {
                let tile = self.backend.max_prefill().max(1);
                let mut offset = cached;
                for chunk in ctx[cached..].chunks(tile) {
                    let tile_t0 = self.backend.now_ms();
                    match self.backend.prefill_continue(chunk, offset) {
                        Ok(o) => {
                            self.trace.span(
                                TraceLane::Host,
                                "prefill_tile",
                                tile_t0,
                                self.backend.now_ms(),
                                Some(rid.0),
                                Some(class),
                                chunk.len() as f64,
                            );
                            offset += chunk.len();
                            let (n, ft) = self.install_tile(
                                rid, total_max, o, &mut hit, &mut installed,
                            )?;
                            total_len += n;
                            first_token = ft;
                        }
                        Err(e) => {
                            backend_err = Some(e);
                            break;
                        }
                    }
                }
            }
        }
        if let Some(e) = backend_err {
            // a hit the first tile never consumed still pins pages;
            // a partially installed sequence is freed by the caller's
            // error path (`step` retires the request and frees its KV)
            if let Some(h) = hit.take() {
                self.pool.release_hit(h);
            }
            return Err(e);
        }
        debug_assert!(
            installed,
            "prefill ran zero tiles (lookup_prefix caps hits below the \
             full context, so a suffix always remains)"
        );
        if use_cache && !resume {
            // ctx == prompt on the non-resume path
            self.pool.register_prefix(rid.0, &ctx);
            self.obs.counter_add("prefix_lookups", Some(class), 1.0);
        }
        if cached > 0 && !resume {
            self.acc.prefix_hits += 1;
            self.acc.prefix_tokens_saved += cached;
            self.trace.instant(
                "prefix_hit",
                t0,
                Some(rid.0),
                Some(class),
                cached as f64,
            );
            if self.obs.enabled() {
                self.obs.counter_add("prefix_hits", Some(class), 1.0);
                self.obs.counter_add(
                    "prefix_tokens_saved",
                    Some(class),
                    cached as f64,
                );
            }
        }
        let now = self.backend.now_ms();
        // one span per prefill call; the name says how the context got
        // here: fresh compute, preemption recovery (swap restore vs
        // recompute re-prefill), or a migrated-KV install
        let span_name = match (charge.is_some(), resume) {
            (false, false) => "prefill",
            (false, true) => "recompute",
            (true, true) => "restore",
            (true, false) => "kv_install",
        };
        self.trace.span(
            TraceLane::Host,
            span_name,
            t0,
            now,
            Some(rid.0),
            Some(class),
            (ctx.len() - cached) as f64,
        );
        let req = self.requests.get_mut(&rid.0).unwrap();
        req.pos = total_len;
        // the installed context ends one slot short of the pending
        // token on both paths; a fresh prefill additionally emits the
        // first token here, a resume already holds its tokens
        if !resume {
            req.cached_prefix_tokens = cached;
            req.generated.push(first_token);
            req.first_token_ms = Some(now);
            self.trace.instant(
                "first_token",
                now,
                Some(rid.0),
                Some(class),
                first_token as f64,
            );
        }
        req.pos += 1; // KV slot for the pending token is written by decode
        // a migrated-KV charge is consumed by the install: if this
        // request is later preempted under a recompute policy it must
        // re-prefill, not re-install at a stale charge
        req.prefill_charge_ms = None;
        req.state = State::Decoding;
        self.acc.prefill_ms += now - t0;
        Ok(())
    }

    /// Install one prefill tile's output into the pool.  The first
    /// tile allocates the sequence's page table -- keys quantize in
    /// the smoothed domain, so a prefix hit must keep the cached
    /// pages' factors (they were packed under them; the hit gives its
    /// copy up and alloc_seq consumes the hit) while a fresh prefill
    /// takes the factors from that first tile.  Every tile then packs
    /// its tokens layer-by-layer; the caller drops the float buffers
    /// before the next tile runs.  Returns (tokens installed, tile's
    /// emitted token).
    fn install_tile(
        &mut self,
        rid: RequestId,
        total_max: usize,
        mut out: PrefillOut,
        hit: &mut Option<PrefixHit>,
        installed: &mut bool,
    ) -> Result<(usize, i32)> {
        if !*installed {
            let (smooth, h) = match hit.take() {
                Some(mut h) => {
                    let s = std::mem::take(&mut h.smooth);
                    (s, Some(h))
                }
                None => (std::mem::take(&mut out.smooth), None),
            };
            self.pool.alloc_seq(rid.0, smooth, total_max, h)?;
            *installed = true;
        }
        let (layers, kvd) = (self.model.layers, self.model.kv_dim());
        for t in 0..out.true_len {
            for l in 0..layers {
                let off = (l * out.true_len + t) * kvd;
                self.pool.push_token(
                    rid.0,
                    l,
                    &out.k[off..off + kvd],
                    &out.v[off..off + kvd],
                )?;
            }
            self.pool.commit_token(rid.0)?;
        }
        Ok((out.true_len, out.first_token))
    }

    /// Free a request's KV everywhere it is tracked: the pool's page
    /// table and (on tiered engines) the residency overlay.
    fn free_kv(&mut self, rid: RequestId) {
        self.pool.free(rid.0);
        if let Some(ts) = self.tier.as_mut() {
            ts.tier.free(rid.0);
        }
    }

    /// Retire a finished request at `now`: stamp completion, record
    /// its latency samples, free the lane and the KV reservation.
    fn retire_finished(&mut self, rid: RequestId, now: f64) {
        let req = self.requests.get_mut(&rid.0).unwrap();
        req.state = State::Finished;
        req.finished_ms = Some(now);
        if let Some(t) = req.ttft_ms() {
            self.acc.ttft.push(t);
        }
        if let Some(t) = req.tpot_ms() {
            self.acc.tpot.push(t);
        }
        self.acc.completed += 1;
        let (class, generated) = {
            let r = &self.requests[&rid.0];
            (r.class, r.generated.len())
        };
        self.trace.instant(
            "retire",
            now,
            Some(rid.0),
            Some(class),
            generated as f64,
        );
        if self.obs.enabled() {
            let r = &self.requests[&rid.0];
            if let Some(ttft) = r.ttft_ms() {
                self.obs.request_finished(class, ttft, r.tpot_ms());
            }
            self.obs.counter_add("tokens_emitted", None, generated as f64);
        }
        self.batcher.retire(rid);
        self.free_kv(rid);
    }

    /// Pick a preemption victim for a newcomer of `newcomer_rank`:
    /// active decodes of *strictly* lower priority (an aged request is
    /// promoted to rank 0 and becomes unpreemptible -- the
    /// anti-starvation floor), excluding requests already done (they
    /// retire this step and release their pages anyway).
    fn select_victim(&self, newcomer_rank: u8) -> Option<RequestId> {
        let s = self.sched.as_ref()?;
        let now = self.backend.now_ms();
        let cands: Vec<VictimCandidate> = self
            .batcher
            .active()
            .iter()
            .filter_map(|rid| {
                let r = self.requests.get(&rid.0)?;
                if r.state != State::Decoding || r.done(self.ctx_cap) {
                    return None;
                }
                let rank = effective_rank(r, now, s.aging_ms);
                if rank <= newcomer_rank {
                    return None;
                }
                let kv_tokens = self.pool.seq_len(rid.0).unwrap_or(0);
                Some(VictimCandidate {
                    rid: rid.0,
                    class: r.class,
                    rank,
                    generated: r.generated.len(),
                    kv_pages: kv_tokens.div_ceil(PAGE_TOKENS).max(1),
                })
            })
            .collect();
        let i = s.victim.select(&cands)?;
        Some(RequestId(cands[i].rid))
    }

    /// Evict one in-flight decode: release its pool pages (its cached
    /// prompt pages survive as reclaimable prefix-cache pages), bounce
    /// it to the queue head, and record how its context comes back --
    /// recompute re-prefills it, swap re-installs it at a modeled
    /// slow-tier transfer charge.
    fn preempt(&mut self, rid: RequestId) -> Result<()> {
        let kv_tokens = self.pool.seq_len(rid.0).unwrap_or(0);
        let pages = kv_tokens.div_ceil(PAGE_TOKENS).max(1);
        let (mode, swap_ms) = {
            let s = self.sched.as_ref().expect("preempt without scheduler");
            let mode = s.victim.mode();
            let ms = match mode {
                // the restore hop is the charged, admission-blocking
                // leg; swap-out streams out asynchronously behind the
                // ongoing decode
                VictimMode::Swap => Some(crate::sched::swap_restore_ms(
                    &s.hbm,
                    &self.model,
                    kv_tokens,
                )),
                VictimMode::Recompute => None,
            };
            (mode, ms)
        };
        self.free_kv(rid);
        self.batcher.requeue_front(rid);
        let req = self
            .requests
            .get_mut(&rid.0)
            .ok_or(P3Error::UnknownRequest(rid.0))?;
        req.state = State::Queued;
        req.preemptions += 1;
        self.acc.preemptions += 1;
        let class = req.class;
        match mode {
            VictimMode::Recompute => {
                req.pages_recomputed += pages;
                self.acc.pages_recomputed += pages;
                req.prefill_charge_ms = None;
            }
            VictimMode::Swap => {
                req.pages_swapped += pages;
                self.acc.pages_swapped += pages;
                req.prefill_charge_ms = swap_ms;
            }
        }
        self.trace.instant(
            mode.event_name(),
            self.backend.now_ms(),
            Some(rid.0),
            Some(class),
            pages as f64,
        );
        if self.obs.enabled() {
            self.obs.counter_add("preempted", Some(class), 1.0);
            self.obs.counter_add(
                "pages_evicted",
                Some(class),
                pages as f64,
            );
        }
        Ok(())
    }

    /// One engine step: admit (with page-granular KV admission
    /// control), prefill the newcomers, run one batched decode step.
    /// Returns tokens emitted.
    ///
    /// Admission reserves each request's worst-case page need
    /// (`ceil((prompt + max_new) / PAGE_TOKENS)`, context-capped) and
    /// is head-of-line blocking: once one newcomer bounces on the
    /// pool, everything behind it bounces too, so FIFO order survives
    /// heterogeneous request sizes.
    pub fn step(&mut self) -> Result<usize> {
        let newly = match &self.sched {
            Some(s) => {
                // priority admission: effective rank (class, promoted
                // by aging), then submit time -- FIFO within a tier
                let now = self.backend.now_ms();
                let aging = s.aging_ms;
                let reqs = &self.requests;
                self.batcher.admit_by(|rid| {
                    let r = &reqs[&rid.0];
                    (
                        effective_rank(r, now, aging),
                        r.submitted_ms.to_bits(),
                        rid.0,
                    )
                })
            }
            None => self.batcher.admit(),
        };
        let mut bounced = vec![];
        let mut prefilled = vec![];
        let mut blocked = false;
        for rid in newly {
            let (total_max, rank) = {
                let req = &self.requests[&rid.0];
                let now = self.backend.now_ms();
                let rank = match &self.sched {
                    Some(s) => effective_rank(req, now, s.aging_ms),
                    None => u8::MAX,
                };
                (
                    (req.prompt.len() + req.max_new_tokens).min(self.ctx_cap),
                    rank,
                )
            };
            // under KV pressure from a higher tier, evict low-priority
            // in-flight decodes until the newcomer fits (each round
            // shrinks the active set, so this terminates)
            if self.sched.is_some() && !blocked {
                while !self.pool.can_admit(total_max) {
                    match self.select_victim(rank) {
                        Some(vid) => self.preempt(vid)?,
                        None => break,
                    }
                }
            }
            if blocked || !self.pool.can_admit(total_max) {
                // a bounce always has something to wait for: with no
                // live sequences every page is obtainable (cached
                // pages are reclaimable) and build() guaranteed one
                // full-context request fits, so an empty pool admits
                // any request
                debug_assert!(
                    blocked || !self.pool.is_empty(),
                    "empty pool refused a request build() sized for"
                );
                blocked = true;
                if self.trace.enabled() {
                    self.trace.instant(
                        "bounce",
                        self.backend.now_ms(),
                        Some(rid.0),
                        Some(self.requests[&rid.0].class),
                        total_max as f64,
                    );
                }
                if self.obs.enabled() {
                    self.obs.counter_add(
                        "bounced",
                        Some(self.requests[&rid.0].class),
                        1.0,
                    );
                }
                bounced.push(rid);
                continue;
            }
            if self.trace.enabled() {
                self.trace.instant(
                    "admit",
                    self.backend.now_ms(),
                    Some(rid.0),
                    Some(self.requests[&rid.0].class),
                    total_max as f64,
                );
            }
            if self.obs.enabled() {
                let class = self.requests[&rid.0].class;
                self.obs.counter_add("admitted", Some(class), 1.0);
                // rank below the class's static one = the aging floor
                // promoted this request past its tier
                if rank < class.rank() {
                    self.obs.counter_add(
                        "aging_promoted",
                        Some(class),
                        1.0,
                    );
                }
            }
            if let Err(e) = self.prefill(rid) {
                // keep the engine consistent on a failed prefill: the
                // lane must not stay active with no KV entry / pos 0
                self.batcher.retire(rid);
                self.free_kv(rid);
                if let Some(r) = self.requests.get_mut(&rid.0) {
                    r.state = State::Finished;
                }
                if self.trace.enabled() {
                    let class =
                        self.requests.get(&rid.0).map(|r| r.class);
                    self.trace.instant(
                        "error",
                        self.backend.now_ms(),
                        Some(rid.0),
                        class,
                        0.0,
                    );
                }
                return Err(e);
            }
            prefilled.push(rid);
        }
        // re-queue rejected requests in their original order
        for rid in bounced.into_iter().rev() {
            self.batcher.requeue_front(rid);
        }
        // a request satisfied by prefill alone (max_new == 1, or the
        // prompt filled its context) retires without burning a decode
        // step on a lane that would overshoot its token budget
        for rid in prefilled {
            let now = self.backend.now_ms();
            let done = self
                .requests
                .get(&rid.0)
                .is_some_and(|r| r.done(self.ctx_cap));
            if done {
                self.retire_finished(rid, now);
            }
        }

        let active: Vec<RequestId> = self.batcher.active().to_vec();
        if active.is_empty() {
            // keep the scrape clock (and alert evaluation) advancing
            // through idle gaps the load runner fast-forwards over
            self.obs.maybe_scrape(self.backend.now_ms());
            return Ok(0);
        }
        // tiered KV: walk each active lane's page table ahead of the
        // decode step.  Prefetched pages were pulled back overlapped
        // with the previous step's compute (a span on the cxl lane,
        // no clock charge); demand misses serialize on the link and
        // stall the engine clock before the step runs.
        let (mut stall_a, mut stall_b, mut serial_stall) =
            (0.0f64, 0.0f64, 0.0f64);
        if let Some(ts) = self.tier.as_mut() {
            let walk_t0 = self.backend.now_ms();
            let mut cursor = walk_t0;
            // per-sub-batch stall frontiers: under interleaving only
            // the sub-batch owning a missing page waits for it (even
            // lane index -> A, odd -> B -- the decode split below)
            let (mut end_a, mut end_b) = (walk_t0, walk_t0);
            for (idx, rid) in active.iter().enumerate() {
                let tokens = self.pool.seq_len(rid.0).unwrap_or(0);
                let npages = tokens.div_ceil(PAGE_TOKENS).max(1);
                let o = ts.tier.step_lane(rid.0, npages);
                if o.prefetched == 0 && o.demand == 0 {
                    continue;
                }
                let req = self.requests.get_mut(&rid.0).unwrap();
                req.pages_prefetched += o.prefetched;
                req.pages_demand += o.demand;
                self.acc.pages_prefetched += o.prefetched;
                self.acc.pages_demand += o.demand;
                let class = req.class;
                if self.obs.enabled() {
                    self.obs.counter_add(
                        "pages_prefetched",
                        Some(class),
                        o.prefetched as f64,
                    );
                    self.obs.counter_add(
                        "pages_demand",
                        Some(class),
                        o.demand as f64,
                    );
                    self.obs.counter_add(
                        "cxl_busy_ms",
                        None,
                        (o.prefetched + o.demand) as f64 * ts.page_ms,
                    );
                }
                if o.prefetched > 0 {
                    self.trace.span(
                        TraceLane::Cxl,
                        "prefetch",
                        walk_t0,
                        walk_t0 + o.prefetched as f64 * ts.page_ms,
                        Some(rid.0),
                        Some(class),
                        o.prefetched as f64,
                    );
                }
                if o.demand > 0 {
                    let stall = o.demand as f64 * ts.page_ms;
                    self.trace.span(
                        TraceLane::Cxl,
                        "demand_migrate",
                        cursor,
                        cursor + stall,
                        Some(rid.0),
                        Some(class),
                        o.demand as f64,
                    );
                    cursor += stall;
                    if idx % 2 == 0 {
                        end_a = cursor;
                    } else {
                        end_b = cursor;
                    }
                }
            }
            if self.interleave {
                // the backend folds the stalls into the interleaved
                // step's critical path (or the serialized stall into
                // the fused fallback) -- no engine-clock charge here
                stall_a = end_a - walk_t0;
                stall_b = end_b - walk_t0;
                serial_stall = cursor - walk_t0;
            } else if cursor > walk_t0 {
                self.backend.advance_to(cursor);
            }
        }
        let t0 = self.backend.now_ms();
        let lanes: Vec<Lane> = active
            .iter()
            .map(|rid| {
                let req = &self.requests[&rid.0];
                Lane {
                    rid: rid.0,
                    last_token: req.last_token(),
                    // slot for the pending token
                    pos: req.pos - 1,
                }
            })
            .collect();
        let out = if self.interleave {
            // even-index lanes -> sub-batch A, odd -> B: A's NPU phase
            // overlaps B's PIM phase and vice versa in the backend
            let (mut la, mut lb) = (Vec::new(), Vec::new());
            for (i, l) in lanes.iter().enumerate() {
                if i % 2 == 0 {
                    la.push(*l);
                } else {
                    lb.push(*l);
                }
            }
            self.backend.decode_step_interleaved(
                &la,
                &lb,
                stall_a,
                stall_b,
                serial_stall,
                &self.pool,
            )?
        } else {
            self.backend.decode_step(&lanes, &self.pool)?
        };
        if out.tokens.len() != lanes.len() {
            return Err(P3Error::Serve(format!(
                "backend returned {} tokens for {} lanes",
                out.tokens.len(),
                lanes.len()
            )));
        }
        let (layers, kvd) = (self.model.layers, self.model.kv_dim());
        let n = lanes.len();
        // interleaved steps return rows in sub-batch A ++ B order;
        // remap each active lane to its row so the install/retire loop
        // keeps running in active (admission) order in both modes
        let n_a = n.div_ceil(2);
        let ilv = self.interleave;
        let row = move |lane: usize| {
            if !ilv {
                lane
            } else if lane % 2 == 0 {
                lane / 2
            } else {
                n_a + lane / 2
            }
        };
        let now = self.backend.now_ms();
        let mut emitted = 0;
        for (lane, rid) in active.iter().enumerate() {
            let r = row(lane);
            // store the k/v of the token we just processed (the pool
            // allocates pages at boundaries from the request's
            // admission-time reservation)
            for layer in 0..layers {
                let off = (layer * n + r) * kvd;
                self.pool.push_token(
                    rid.0,
                    layer,
                    &out.new_k[off..off + kvd],
                    &out.new_v[off..off + kvd],
                )?;
            }
            self.pool.commit_token(rid.0)?;
            let req = self.requests.get_mut(&rid.0).unwrap();
            req.generated.push(out.tokens[r]);
            req.pos += 1;
            emitted += 1;
            if self.trace.enabled() {
                self.trace.instant(
                    "token",
                    now,
                    Some(rid.0),
                    Some(req.class),
                    req.generated.len() as f64,
                );
            }
            if req.done(self.ctx_cap) {
                self.retire_finished(*rid, now);
            }
        }
        self.acc.decode_steps += 1;
        self.acc.tokens_out += emitted;
        // measured after the KV append loop so the host-side INT4
        // pack work stays inside decode_ms (as in the original engine)
        let t1 = self.backend.now_ms();
        self.acc.decode_ms += t1 - t0;
        if self.trace.enabled() {
            self.trace.span(
                TraceLane::Host,
                "decode_step",
                t0,
                t1,
                None,
                None,
                n as f64,
            );
            let (used, cached, _live) = self.pool.occupancy();
            let (queued, active) = self.batcher.depths();
            self.trace.counter("kv_used_bytes", t1, used as f64);
            self.trace.counter("kv_cached_bytes", t1, cached as f64);
            self.trace.counter("queue_depth", t1, queued as f64);
            self.trace.counter("active_lanes", t1, active as f64);
        }
        if self.obs.enabled() {
            let (used, cached, _live) = self.pool.occupancy();
            let (queued, active_n) = self.batcher.depths();
            self.obs.gauge_set("kv_used_bytes", None, used as f64);
            self.obs.gauge_set("kv_cached_bytes", None, cached as f64);
            self.obs.gauge_set("queue_depth", None, queued as f64);
            self.obs.gauge_set("active_lanes", None, active_n as f64);
            if let Some((hot, cold, _cap)) = self.tier_occupancy() {
                self.obs.gauge_set("kv_hot_pages", None, hot as f64);
                self.obs.gauge_set("kv_cold_pages", None, cold as f64);
            }
            if self.interleave {
                let ilv = self.backend.interleave_stats();
                self.obs.gauge_set(
                    "overlap_factor",
                    None,
                    ilv.overlap_factor(),
                );
                self.obs.gauge_set(
                    "fused_steps",
                    None,
                    ilv.fused_steps as f64,
                );
            }
        }
        self.obs.maybe_scrape(t1);
        Ok(emitted)
    }

    /// Run until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Metrics> {
        let mut guard = 0usize;
        while !self.batcher.idle() {
            self.step()?;
            guard += 1;
            if guard > 1_000_000 {
                return Err(P3Error::Serve(
                    "serve loop did not converge".into(),
                ));
            }
        }
        Ok(self.metrics())
    }

    /// Debug-only counter audit with the event stream as ground truth:
    /// the hand-maintained prefix-cache and preemption aggregates in
    /// [`Metrics`] must equal what telemetry recorded, so the two can
    /// never silently diverge.  Skipped when tracing is off or the
    /// bounded sink dropped events (the stream is then incomplete by
    /// design).
    #[cfg(debug_assertions)]
    fn audit_counters(&self) {
        if !self.trace.enabled() || self.trace.dropped() > 0 {
            return;
        }
        let rep = self.trace.replica_id();
        let evs = self.trace.snapshot();
        let count = |name: &str| {
            evs.iter()
                .filter(|e| e.replica == rep && e.name == name)
                .count()
        };
        let sum = |name: &str| -> f64 {
            evs.iter()
                .filter(|e| e.replica == rep && e.name == name)
                .map(|e| e.value)
                .sum()
        };
        debug_assert_eq!(
            count("prefix_hit"),
            self.acc.prefix_hits,
            "Metrics.prefix_hits drifted from the trace's prefix_hit \
             events"
        );
        debug_assert_eq!(
            sum("prefix_hit") as usize,
            self.acc.prefix_tokens_saved,
            "Metrics.prefix_tokens_saved drifted from the trace's \
             prefix_hit token counts"
        );
        debug_assert_eq!(
            count("preempt:swap") + count("preempt:recompute"),
            self.acc.preemptions,
            "Metrics.preemptions drifted from the trace's preempt \
             events"
        );
        debug_assert_eq!(
            sum("preempt:swap") as usize,
            self.acc.pages_swapped,
            "Metrics.pages_swapped drifted from the trace's \
             preempt:swap page counts"
        );
        debug_assert_eq!(
            sum("preempt:recompute") as usize,
            self.acc.pages_recomputed,
            "Metrics.pages_recomputed drifted from the trace's \
             preempt:recompute page counts"
        );
        debug_assert_eq!(
            sum("prefetch") as usize,
            self.acc.pages_prefetched,
            "Metrics.pages_prefetched drifted from the cxl lane's \
             prefetch page counts"
        );
        debug_assert_eq!(
            sum("demand_migrate") as usize,
            self.acc.pages_demand,
            "Metrics.pages_demand drifted from the cxl lane's \
             demand_migrate page counts"
        );
    }

    /// Metrics snapshot (callable mid-run; distributions cover retired
    /// requests only).
    pub fn metrics(&self) -> Metrics {
        #[cfg(debug_assertions)]
        self.audit_counters();
        let ilv = self.backend.interleave_stats();
        Metrics {
            backend: self.backend.name(),
            completed: self.acc.completed,
            decode_steps: self.acc.decode_steps,
            tokens_out: self.acc.tokens_out,
            wall_ms: self.backend.now_ms(),
            prefill_ms: self.acc.prefill_ms,
            decode_ms: self.acc.decode_ms,
            prefix_hits: self.acc.prefix_hits,
            prefix_tokens_saved: self.acc.prefix_tokens_saved,
            preemptions: self.acc.preemptions,
            pages_swapped: self.acc.pages_swapped,
            pages_recomputed: self.acc.pages_recomputed,
            pages_prefetched: self.acc.pages_prefetched,
            pages_demand: self.acc.pages_demand,
            npu_busy_ms: ilv.npu_busy_ms,
            pim_busy_ms: ilv.pim_busy_ms,
            overlap_ms: ilv.overlap_ms,
            interleaved_steps: ilv.interleaved_steps,
            fused_steps: ilv.fused_steps,
            serial_saved_ms: ilv.serial_saved_ms,
            ttft_ms: Percentiles::from_samples(&self.acc.ttft),
            per_token_ms: Percentiles::from_samples(&self.acc.tpot),
        }
    }

    /// NPU/PIM operator mapping of the latest decode step (sim backend).
    pub fn mapping_summary(&self) -> Option<MapSummary> {
        self.backend.mapping_summary()
    }

    /// Packed bytes live sequences hold in the KV pool (shared pages
    /// counted once; reclaimable cache-only pages excluded).
    pub fn pool_used_bytes(&self) -> usize {
        self.pool.used_bytes()
    }

    /// Packed bytes held by cache-only prefix pages (reclaimed by LRU
    /// eviction under pool pressure).
    pub fn pool_cached_bytes(&self) -> usize {
        self.pool.cached_bytes()
    }

    /// Live KV sequences (== lanes holding pool pages).
    pub fn kv_entries(&self) -> usize {
        self.pool.len()
    }

    /// Is shared-prefix KV caching enabled on this engine?
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_cache
    }

    /// Is NPU‖PIM sub-batch interleaving enabled on this engine?
    pub fn interleave_enabled(&self) -> bool {
        self.interleave
    }

    /// Name of the active victim policy (None = FIFO, no preemption).
    pub fn victim_policy(&self) -> Option<&'static str> {
        self.sched.as_ref().map(|s| s.victim.name())
    }

    /// `(hot pages, cold pages, hot-tier page cap)` of the tiered KV
    /// hierarchy; `None` on a single-tier engine.
    pub fn tier_occupancy(&self) -> Option<(usize, usize, usize)> {
        self.tier.as_ref().map(|t| {
            (
                t.tier.hot_pages(),
                t.tier.cold_pages(),
                t.tier.hot_cap_pages(),
            )
        })
    }
}

/// Typed builder for the serving engine: model + scheme by name from
/// the registries, backend selection, batching and KV-capacity knobs,
/// validation at `build()`.  Replaces the old pub-field `EngineConfig`
/// struct-literal construction.
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    kind: BackendKind,
    artifacts_dir: String,
    model: Option<String>,
    scheme: Option<String>,
    system: Option<String>,
    device_weights: bool,
    max_batch: usize,
    kv_capacity: usize,
    ctx_limit: Option<usize>,
    /// None = backend default: on for sim, off for PJRT (whose
    /// suffix-only prefill is a documented approximation)
    prefix_cache: Option<bool>,
    /// victim-policy registry name (None = FIFO, no preemption)
    victim: Option<String>,
    /// anti-starvation floor override (ms on the engine clock)
    aging_ms: Option<f64>,
    /// hot-tier fraction of the pool's pages (None = single-tier)
    hot_fraction: Option<f64>,
    /// ahead-of-decode prefetch depth in pages per lane per step
    prefetch_depth: Option<usize>,
    /// NPU/PIM sub-batch interleaving (sim backend; default off)
    interleave: bool,
    /// telemetry handle installed at build (default off)
    trace: Trace,
    /// observability handle installed at build (default off)
    obs: Obs,
}

impl EngineBuilder {
    fn new(kind: BackendKind) -> Self {
        EngineBuilder {
            kind,
            artifacts_dir: "artifacts".into(),
            model: None,
            scheme: None,
            system: None,
            device_weights: true,
            max_batch: 8,
            kv_capacity: 64 << 20,
            ctx_limit: None,
            prefix_cache: None,
            victim: None,
            aging_ms: None,
            hot_fraction: None,
            prefetch_depth: None,
            interleave: false,
            trace: Trace::off(),
            obs: Obs::off(),
        }
    }

    /// Real-numerics backend over the AOT PJRT graphs in `artifacts_dir`.
    pub fn pjrt(artifacts_dir: &str) -> Self {
        let mut b = Self::new(BackendKind::Pjrt);
        b.artifacts_dir = artifacts_dir.to_string();
        b
    }

    /// Cost-model backend: any model/scheme/system, simulated time,
    /// no artifacts needed.
    pub fn sim() -> Self {
        Self::new(BackendKind::Sim)
    }

    /// Backend by name ("pjrt" | "sim").
    pub fn backend(name: &str) -> Result<Self> {
        BackendKind::by_name(name)
            .map(Self::new)
            .ok_or_else(|| P3Error::InvalidConfig(format!(
                "unknown backend {name:?} (pjrt | sim)"
            )))
    }

    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.artifacts_dir = dir.to_string();
        self
    }

    /// Model by `config::llm` name (sim backend; PJRT serves the tiny
    /// shipped model only).
    pub fn model(mut self, name: &str) -> Self {
        self.model = Some(name.to_string());
        self
    }

    /// Quantization scheme by `config::scheme` registry name.
    pub fn scheme(mut self, name: &str) -> Self {
        self.scheme = Some(name.to_string());
        self
    }

    /// Modeled hardware system by `accel` registry name (sim backend).
    pub fn system(mut self, name: &str) -> Self {
        self.system = Some(name.to_string());
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    /// KV pool capacity in packed bytes.
    pub fn kv_capacity(mut self, bytes: usize) -> Self {
        self.kv_capacity = bytes;
        self
    }

    /// Cap the per-request context (sim backend): bounds both the KV
    /// reservation and the longest admissible prompt.
    pub fn ctx_limit(mut self, ctx: usize) -> Self {
        self.ctx_limit = Some(ctx);
        self
    }

    /// Persistent device-resident weight buffers (PJRT perf fast path).
    pub fn device_weights(mut self, on: bool) -> Self {
        self.device_weights = on;
        self
    }

    /// Shared-prefix KV caching: prompts starting with an
    /// already-served prefix adopt its cached quantized pages and
    /// prefill only the suffix.  Default **on for the sim backend**
    /// and **off for PJRT** -- the single-tile AOT prefill graph makes
    /// a PJRT cache hit a documented approximation (see
    /// `PjrtBackend::prefill_continue`), so the real-numerics backend
    /// never degrades silently; opt in explicitly to trade exactness
    /// for the skipped prefill.  Disable for A/B comparisons
    /// (`loadtest --no-prefix-cache`, `benches/prefix_cache.rs`).
    pub fn prefix_cache(mut self, on: bool) -> Self {
        self.prefix_cache = Some(on);
        self
    }

    /// Enable SLO-tiered preemptive scheduling (sim backend) with a
    /// victim policy from the `sched` registry (`"recompute"` |
    /// `"swap"`).  Admission then orders the queue by effective
    /// priority rank, and KV pressure from a higher tier evicts
    /// low-priority in-flight decodes.
    pub fn preempt(mut self, victim: &str) -> Self {
        self.victim = Some(victim.to_string());
        self
    }

    /// Anti-starvation floor for preemptive scheduling: a request
    /// queued longer than this many engine-clock ms is promoted to top
    /// effective rank (first in line, unpreemptible).  Default 1000
    /// ms; `f64::INFINITY` disables aging.
    pub fn aging_ms(mut self, ms: f64) -> Self {
        self.aging_ms = Some(ms);
        self
    }

    /// Enable the two-tier KV hierarchy (sim backend): this fraction
    /// of the pool's pages stays resident in PIM-attached HBM (the hot
    /// tier); the rest of the combined capacity lives in the CXL/DDR
    /// cold pool and pages migrate at the modeled link cost (see
    /// [`crate::mem`]).  Admission overcommits HBM against the cold
    /// pool -- `KvExhausted` fires only when *both* tiers are full.
    /// Must be in `(0, 1]`; unset keeps the engine single-tier.
    pub fn hot_fraction(mut self, f: f64) -> Self {
        self.hot_fraction = Some(f);
        self
    }

    /// Pages per lane per step the ahead-of-decode prefetcher pulls
    /// back from the cold tier before the step that reads them,
    /// overlapped with the previous step's compute (no stall).  Cold
    /// pages past the depth demand-migrate and stall the engine clock.
    /// Requires [`hot_fraction`](EngineBuilder::hot_fraction); the
    /// default 0 is pure demand paging.
    pub fn prefetch_depth(mut self, pages: usize) -> Self {
        self.prefetch_depth = Some(pages);
        self
    }

    /// NPU‖PIM sub-batch interleaving (sim backend): split each decode
    /// step's lanes into two sub-batches whose engine phases run
    /// concurrently -- sub-batch A's NPU work overlaps B's PIM work
    /// and vice versa -- and charge the critical path across both
    /// timelines instead of the serial sum.  Steps where the split
    /// schedule would lose (e.g. PIM weight-streaming passes conserve
    /// across the split) fuse back to the serial charge, so
    /// interleaving never regresses a step.  Default off; `false` is
    /// bit-identical to the pre-interleave engine.
    pub fn interleave(mut self, on: bool) -> Self {
        self.interleave = on;
        self
    }

    /// Install a telemetry handle on the built engine (and its
    /// backend, for the NPU/PIM/bus device lanes).  Keep a clone to
    /// read the trace after the run; the default-off handle records
    /// nothing and costs nothing.  See [`crate::telemetry`].
    pub fn telemetry(mut self, trace: Trace) -> Self {
        self.trace = trace;
        self
    }

    /// Install an observability handle on the built engine: the
    /// metrics registry fills as the engine serves, the scraper runs
    /// on the engine clock, and SLO burn-rate alerts evaluate at each
    /// scrape.  Keep a clone to export Prometheus text / series JSON
    /// after the run; the default-off handle records nothing and costs
    /// nothing.  See [`crate::obs`].
    pub fn observe(mut self, obs: Obs) -> Self {
        self.obs = obs;
        self
    }

    pub fn build(self) -> Result<Engine> {
        let scheme_name = self.scheme.as_deref().unwrap_or("p3llm");
        let scheme = scheme::by_name(scheme_name)
            .ok_or_else(|| P3Error::UnknownScheme(scheme_name.into()))?;
        if self.aging_ms.is_some() && self.victim.is_none() {
            return Err(P3Error::InvalidConfig(
                "aging_ms requires a victim policy (preempt(..))".into(),
            ));
        }
        if self.prefetch_depth.is_some() && self.hot_fraction.is_none() {
            return Err(P3Error::InvalidConfig(
                "prefetch_depth requires a tiered KV hierarchy \
                 (hot_fraction(..))"
                    .into(),
            ));
        }
        match self.kind {
            BackendKind::Pjrt => {
                if self.victim.is_some() {
                    return Err(P3Error::InvalidConfig(
                        "preemptive scheduling is a sim-backend knob \
                         (the PJRT decode graphs cannot drop and \
                         restore lanes mid-flight)"
                            .into(),
                    ));
                }
                if let Some(m) = self.model.as_deref() {
                    if !m.eq_ignore_ascii_case("tiny-1M") {
                        return Err(P3Error::InvalidConfig(format!(
                            "the PJRT backend serves the AOT-compiled \
                             tiny-1M model only (got {m:?}); use the sim \
                             backend for other models"
                        )));
                    }
                }
                if self.ctx_limit.is_some() {
                    return Err(P3Error::InvalidConfig(
                        "ctx_limit is a sim-backend knob (the PJRT decode \
                         graphs are compiled for the model's full context)"
                            .into(),
                    ));
                }
                if self.hot_fraction.is_some() {
                    return Err(P3Error::InvalidConfig(
                        "the tiered KV hierarchy (hot_fraction / \
                         prefetch_depth) is a sim-backend knob (PJRT \
                         serves from device HBM only)"
                            .into(),
                    ));
                }
                if self.system.is_some() {
                    return Err(P3Error::InvalidConfig(
                        "system selection is a sim-backend knob".into(),
                    ));
                }
                if self.interleave {
                    return Err(P3Error::InvalidConfig(
                        "NPU/PIM sub-batch interleaving is a sim-backend \
                         knob (the PJRT backend has one wall clock, not \
                         two device timelines)"
                            .into(),
                    ));
                }
                if !COMPILED_BATCHES.contains(&self.max_batch) {
                    return Err(P3Error::InvalidConfig(format!(
                        "PJRT max_batch must be one of {COMPILED_BATCHES:?} \
                         (AOT graph batch sizes), got {}",
                        self.max_batch
                    )));
                }
                // the AOT graph set covers FP16 and the P3 W4A8KV4P8
                // pipeline; other schemes have no compiled variant
                let quantized = match scheme.name {
                    "FP16" => false,
                    "P3-LLM-W4A8KV4P8" => true,
                    other => {
                        return Err(P3Error::InvalidConfig(format!(
                            "PJRT backend has AOT graphs for schemes \
                             fp16 | p3llm only (got {other})"
                        )))
                    }
                };
                let backend = PjrtBackend::new(
                    &self.artifacts_dir,
                    quantized,
                    self.device_weights,
                )?;
                let mut eng = Engine::with_backend(
                    Box::new(backend),
                    self.max_batch,
                    self.kv_capacity,
                    None,
                    // exact numerics by default; caching is explicit
                    // opt-in on the real-numerics backend
                    self.prefix_cache.unwrap_or(false),
                )?;
                eng.set_trace(self.trace.clone());
                eng.set_obs(self.obs.clone());
                Ok(eng)
            }
            BackendKind::Sim => {
                let model_name = self.model.as_deref().unwrap_or("tiny-1M");
                let model = crate::config::llm::by_name(model_name)
                    .ok_or_else(|| P3Error::UnknownModel(model_name.into()))?;
                let system_name = self.system.as_deref().unwrap_or("P3-LLM");
                let mut accel = crate::accel::by_name(system_name)
                    .ok_or_else(|| P3Error::UnknownSystem(system_name.into()))?;
                if self.scheme.is_some() {
                    // explicit scheme overrides the system's default
                    accel.scheme = scheme;
                }
                let ctx_cap = self
                    .ctx_limit
                    .unwrap_or_else(|| model.max_ctx.min(1024));
                if ctx_cap > model.max_ctx {
                    return Err(P3Error::InvalidConfig(format!(
                        "ctx_limit {ctx_cap} exceeds {}'s max context {}",
                        model.name, model.max_ctx
                    )));
                }
                let sched = match &self.victim {
                    Some(v) => {
                        let victim = crate::sched::victim_by_name(v)
                            .ok_or_else(|| {
                                P3Error::InvalidConfig(format!(
                                    "unknown victim policy {v:?} \
                                     (recompute | swap)"
                                ))
                            })?;
                        let aging_ms = self.aging_ms.unwrap_or(1_000.0);
                        if !(aging_ms > 0.0) {
                            return Err(P3Error::InvalidConfig(format!(
                                "aging_ms must be > 0 (INFINITY disables \
                                 aging), got {aging_ms}"
                            )));
                        }
                        Some(SchedState {
                            victim,
                            aging_ms,
                            hbm: accel.system.hbm.clone(),
                        })
                    }
                    None => None,
                };
                // price the per-page CXL migration once, before the
                // backend takes ownership of the configs
                let tier_cfg = match self.hot_fraction {
                    Some(f) => {
                        if !f.is_finite() || f <= 0.0 || f > 1.0 {
                            return Err(P3Error::InvalidConfig(format!(
                                "hot_fraction must be in (0, 1], got {f}"
                            )));
                        }
                        let page_ms = crate::mem::page_migration_ms(
                            &accel.system.hbm,
                            &CxlLink::default(),
                            &model,
                        );
                        Some((f, self.prefetch_depth.unwrap_or(0), page_ms))
                    }
                    None => None,
                };
                let backend = SimBackend::new(accel, model, ctx_cap);
                let mut eng = Engine::with_backend(
                    Box::new(backend),
                    self.max_batch,
                    self.kv_capacity,
                    Some(ctx_cap),
                    self.prefix_cache.unwrap_or(true),
                )?;
                eng.sched = sched;
                eng.interleave = self.interleave;
                if let Some((f, depth, page_ms)) = tier_cfg {
                    let cap = (eng.pool.total_pages() as f64 * f).floor()
                        as usize;
                    eng.tier = Some(TierState {
                        tier: TieredKv::new(cap.max(1), depth),
                        page_ms,
                    });
                }
                eng.set_trace(self.trace.clone());
                eng.set_obs(self.obs.clone());
                Ok(eng)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::from_samples(&xs);
        assert_eq!(p.count, 100);
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p95, 95.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.max, 100.0);
        assert!((p.mean - 50.5).abs() < 1e-9);
        let single = Percentiles::from_samples(&[7.0]);
        assert_eq!(single.p50, 7.0);
        assert_eq!(single.p99, 7.0);
        assert_eq!(Percentiles::from_samples(&[]).count, 0);
    }

    #[test]
    fn percentiles_count_0_1_2_are_well_defined() {
        // empty: the all-zero default, every field finite
        let e = Percentiles::from_samples(&[]);
        assert_eq!(e, Percentiles::default());
        for v in [e.mean, e.p50, e.p95, e.p99, e.max] {
            assert!(v.is_finite());
        }
        // one sample: every percentile is that sample
        let one = Percentiles::from_samples(&[3.5]);
        assert_eq!(one.count, 1);
        for v in [one.mean, one.p50, one.p95, one.p99, one.max] {
            assert_eq!(v, 3.5);
        }
        // two samples: nearest-rank puts p50 on the lower, the tail
        // percentiles on the upper
        let two = Percentiles::from_samples(&[4.0, 2.0]);
        assert_eq!(two.count, 2);
        assert_eq!(two.mean, 3.0);
        assert_eq!(two.p50, 2.0);
        assert_eq!(two.p95, 4.0);
        assert_eq!(two.p99, 4.0);
        assert_eq!(two.max, 4.0);
    }

    #[test]
    fn percentiles_exact_nearest_rank_boundaries() {
        // n = 20: rank(p) = ceil(20p/100); p50 -> 10th, p95 -> 19th,
        // p99 -> 20th (1-indexed)
        let xs: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        let p = Percentiles::from_samples(&xs);
        assert_eq!(p.p50, 10.0);
        assert_eq!(p.p95, 19.0);
        assert_eq!(p.p99, 20.0);
        // n = 200: p99 -> 198th element = 198.0
        let xs: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let p = Percentiles::from_samples(&xs);
        assert_eq!(p.p99, 198.0);
    }

    #[test]
    fn percentiles_drop_non_finite_samples_without_panicking() {
        let p = Percentiles::from_samples(&[
            f64::NAN,
            2.0,
            f64::INFINITY,
            1.0,
            f64::NEG_INFINITY,
        ]);
        assert_eq!(p.count, 2);
        assert_eq!(p.p50, 1.0);
        assert_eq!(p.max, 2.0);
        assert!(p.mean.is_finite());
        // all-NaN collapses to the empty default
        assert_eq!(Percentiles::from_samples(&[f64::NAN]).count, 0);
    }

    #[test]
    fn percentiles_merge_empty_singleton_unequal() {
        // empty input set and all-empty parts collapse to the default
        assert_eq!(Percentiles::merge(&[]), Percentiles::default());
        let zero = Percentiles::default();
        assert_eq!(Percentiles::merge(&[&zero, &zero]).count, 0);
        // singleton merge is the identity
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::from_samples(&xs);
        assert_eq!(Percentiles::merge(&[&p]), p);
        // empty parts do not perturb a merge
        assert_eq!(Percentiles::merge(&[&zero, &p, &zero]), p);
        // unequal counts: 100 low samples vs 1 high sample -- the big
        // part brackets the median (the straggler cannot drag it to
        // 1e6), the high straggler owns the max, means merge exactly
        let one = Percentiles::from_samples(&[1e6]);
        let m = Percentiles::merge(&[&p, &one]);
        assert_eq!(m.count, 101);
        assert!(m.p50 >= p.p50 && m.p50 <= p.p95, "{m:?}");
        assert!(m.p50 <= m.p95 && m.p95 <= m.p99 && m.p99 <= m.max);
        assert_eq!(m.max, 1e6);
        let want_mean = (p.mean * 100.0 + 1e6) / 101.0;
        assert!((m.mean - want_mean).abs() < 1e-9);
        // two equal-count parts: percentiles land between the parts'
        let q = Percentiles::from_samples(
            &(101..=200).map(|i| i as f64).collect::<Vec<_>>(),
        );
        let mq = Percentiles::merge(&[&p, &q]);
        assert_eq!(mq.count, 200);
        assert!(mq.p50 >= p.p50 && mq.p50 <= q.p50, "{mq:?}");
        assert!(mq.p95 >= p.p95 && mq.p95 <= q.p99, "{mq:?}");
        assert_eq!(mq.max, 200.0);
        assert!((mq.mean - (p.mean + q.mean) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn chunked_prefill_absorbs_long_prompts_on_sim() {
        // a prompt far beyond one 64-token prefill tile is admitted
        // and served (ceil(len / tile) chunks), not rejected
        let mut eng = EngineBuilder::sim()
            .model("tiny-1M")
            .ctx_limit(512)
            .max_batch(2)
            .build()
            .unwrap();
        assert_eq!(eng.max_prompt(), 511);
        let id = eng.submit(vec![3; 300], 4).unwrap();
        let m = eng.run_to_completion().unwrap();
        assert_eq!(m.completed, 1);
        let st = eng.poll(id).unwrap();
        assert!(st.finished);
        assert_eq!(st.tokens_generated, 4);
        // chunked prefill costs more modeled time than a single tile
        let mut short = EngineBuilder::sim()
            .model("tiny-1M")
            .ctx_limit(512)
            .max_batch(2)
            .build()
            .unwrap();
        short.submit(vec![3; 32], 4).unwrap();
        let ms = short.run_to_completion().unwrap();
        assert!(m.prefill_ms > ms.prefill_ms);
    }

    #[test]
    fn submit_prefilled_charges_transfer_not_compute() {
        let mk = || {
            EngineBuilder::sim()
                .model("tiny-1M")
                .ctx_limit(256)
                .max_batch(2)
                .build()
                .unwrap()
        };
        let prompt = vec![7; 100];
        // real prefill serves the same shape
        let mut a = mk();
        a.submit(prompt.clone(), 3).unwrap();
        let ma = a.run_to_completion().unwrap();
        assert_eq!(ma.completed, 1);
        // migrated KV installs at exactly the given transfer charge
        let mut b = mk();
        let id = b.submit_prefilled(prompt.clone(), 3, 0.25).unwrap();
        let mb = b.run_to_completion().unwrap();
        assert_eq!(mb.completed, 1);
        assert_eq!(b.poll(id).unwrap().tokens_generated, 3);
        assert!((mb.prefill_ms - 0.25).abs() < 1e-9, "{}", mb.prefill_ms);
        let mut b2 = mk();
        b2.submit_prefilled(prompt, 3, 0.5).unwrap();
        let mb2 = b2.run_to_completion().unwrap();
        assert!((mb2.prefill_ms - 0.5).abs() < 1e-9, "{}", mb2.prefill_ms);
        // bad charges are typed errors
        let mut c = mk();
        assert!(matches!(
            c.submit_prefilled(vec![1, 2], 3, f64::NAN),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            c.submit_prefilled(vec![1, 2], 3, -1.0),
            Err(P3Error::InvalidConfig(_))
        ));
    }

    #[test]
    fn single_token_requests_retire_at_prefill() {
        let mut eng = EngineBuilder::sim().ctx_limit(64).build().unwrap();
        let id = eng.submit(vec![1, 2, 3], 1).unwrap();
        let m = eng.run_to_completion().unwrap();
        assert_eq!(m.completed, 1);
        // exactly the one prefill-emitted token, no decode overshoot
        assert_eq!(eng.poll(id).unwrap().tokens_generated, 1);
        assert_eq!(m.tokens_out, 0);
        assert_eq!(m.ttft_ms.count, 1);
        assert_eq!(eng.kv_entries(), 0);
    }

    #[test]
    fn builder_validation_errors_are_typed() {
        assert!(matches!(
            EngineBuilder::sim().scheme("nope").build(),
            Err(P3Error::UnknownScheme(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().model("gpt-17").build(),
            Err(P3Error::UnknownModel(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().system("warp").build(),
            Err(P3Error::UnknownSystem(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().max_batch(0).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        // capacity below one full-context reservation is rejected
        assert!(matches!(
            EngineBuilder::sim().kv_capacity(16).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        // PJRT-only constraints fail before touching artifacts
        assert!(matches!(
            EngineBuilder::pjrt("artifacts").max_batch(3).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::pjrt("artifacts").model("Llama-2-7B").build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::pjrt("artifacts").ctx_limit(64).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::pjrt("artifacts").scheme("awq").build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::backend("cuda"),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(EngineBuilder::backend("sim").is_ok());
        // preemptive-scheduling knobs: sim-only, typed rejections
        assert!(matches!(
            EngineBuilder::pjrt("artifacts").preempt("recompute").build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().preempt("lru").build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().aging_ms(50.0).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().preempt("swap").aging_ms(0.0).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().preempt("swap").aging_ms(f64::NAN).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        // tiered-KV knobs: sim-only, typed rejections
        assert!(matches!(
            EngineBuilder::pjrt("artifacts").hot_fraction(0.5).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            EngineBuilder::sim().prefetch_depth(4).build(),
            Err(P3Error::InvalidConfig(_))
        ));
        for bad in [0.0, -0.25, 1.5, f64::NAN, f64::INFINITY] {
            assert!(
                matches!(
                    EngineBuilder::sim().hot_fraction(bad).build(),
                    Err(P3Error::InvalidConfig(_))
                ),
                "hot_fraction({bad}) should be rejected"
            );
        }
        let tiered = EngineBuilder::sim()
            .hot_fraction(0.5)
            .prefetch_depth(2)
            .build()
            .unwrap();
        let (hot, cold, cap) = tiered.tier_occupancy().unwrap();
        assert_eq!((hot, cold), (0, 0));
        assert!(cap >= 1);
        assert_eq!(
            EngineBuilder::sim().build().unwrap().tier_occupancy(),
            None
        );
        let eng = EngineBuilder::sim()
            .preempt("swap")
            .aging_ms(f64::INFINITY)
            .build()
            .unwrap();
        assert_eq!(eng.victim_policy(), Some("swap"));
        assert_eq!(
            EngineBuilder::sim().build().unwrap().victim_policy(),
            None
        );
    }

    /// Engine sized for exactly two in-flight requests of the test
    /// shape, with the given victim policy and an infinite aging floor
    /// (so promotion never interferes with the preemption under test).
    fn preempt_engine(victim: &str) -> Engine {
        let model = crate::config::llm::TINY;
        let layout = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: 128,
        };
        let per_req = layout.bytes_per_request();
        EngineBuilder::sim()
            .model("tiny-1M")
            .ctx_limit(128)
            .max_batch(4)
            .kv_capacity(per_req * 2)
            .preempt(victim)
            .aging_ms(f64::INFINITY)
            .build()
            .unwrap()
    }

    #[test]
    fn interactive_kv_pressure_preempts_best_effort() {
        for victim in ["recompute", "swap"] {
            let mut eng = preempt_engine(victim);
            // two best-effort requests fill the pool (each reserves
            // ceil(110/16) = 7 of the 16 pages)
            let p1: Vec<i32> = (0..80).map(|i| i % 97).collect();
            let p2: Vec<i32> = (0..80).map(|i| (i + 40) % 89).collect();
            let b1 = eng
                .submit_class(p1, 30, crate::sched::SloClass::BestEffort)
                .unwrap();
            let b2 = eng
                .submit_class(p2, 30, crate::sched::SloClass::BestEffort)
                .unwrap();
            for _ in 0..4 {
                eng.step().unwrap();
            }
            assert_eq!(eng.active_lanes(), 2, "{victim}");
            // an interactive arrival does not fit -> one victim pays
            let p3: Vec<i32> = (0..80).map(|i| (i + 7) % 83).collect();
            let i1 = eng
                .submit_class(p3, 30, crate::sched::SloClass::Interactive)
                .unwrap();
            eng.step().unwrap();
            let m_mid = eng.metrics();
            assert_eq!(m_mid.preemptions, 1, "{victim}");
            assert_eq!(
                eng.request(i1).unwrap().state,
                State::Decoding,
                "{victim}: interactive admitted by eviction"
            );
            let m = eng.run_to_completion().unwrap();
            // conservation: every request finishes with its full
            // budget, nothing lost or duplicated across the eviction
            assert_eq!(m.completed, 3, "{victim}");
            for id in [b1, b2, i1] {
                let st = eng.poll(id).unwrap();
                assert!(st.finished, "{victim}");
                assert_eq!(st.tokens_generated, 30, "{victim}");
            }
            assert_eq!(eng.request(i1).unwrap().preemptions, 0);
            let victim_req = [b1, b2]
                .iter()
                .map(|id| eng.request(*id).unwrap())
                .find(|r| r.preemptions > 0)
                .expect("one best-effort request was evicted");
            match victim {
                "recompute" => {
                    assert!(victim_req.pages_recomputed > 0);
                    assert_eq!(m.pages_swapped, 0);
                    assert_eq!(m.pages_recomputed, victim_req.pages_recomputed);
                }
                _ => {
                    assert!(victim_req.pages_swapped > 0);
                    assert_eq!(m.pages_recomputed, 0);
                    assert_eq!(m.pages_swapped, victim_req.pages_swapped);
                }
            }
            // pool fully released
            assert_eq!(eng.kv_entries(), 0, "{victim}");
            assert_eq!(eng.pool_used_bytes(), 0, "{victim}");
        }
    }

    #[test]
    fn aged_requests_are_unpreemptible() {
        // tiny aging floor: by the time the interactive request
        // arrives, the decoding best-effort requests have aged to
        // rank 0 and cannot be evicted -- the newcomer waits (FIFO
        // degradation) instead
        let model = crate::config::llm::TINY;
        let layout = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: 128,
        };
        let per_req = layout.bytes_per_request();
        let mut eng = EngineBuilder::sim()
            .model("tiny-1M")
            .ctx_limit(128)
            .max_batch(4)
            .kv_capacity(per_req * 2)
            .preempt("recompute")
            .aging_ms(1e-6)
            .build()
            .unwrap();
        let p1: Vec<i32> = (0..80).map(|i| i % 97).collect();
        let p2: Vec<i32> = (0..80).map(|i| (i + 40) % 89).collect();
        eng.submit_class(p1, 30, crate::sched::SloClass::BestEffort)
            .unwrap();
        eng.submit_class(p2, 30, crate::sched::SloClass::BestEffort)
            .unwrap();
        for _ in 0..4 {
            eng.step().unwrap();
        }
        let p3: Vec<i32> = (0..80).map(|i| (i + 7) % 83).collect();
        let i1 = eng
            .submit_class(p3, 30, crate::sched::SloClass::Interactive)
            .unwrap();
        eng.step().unwrap();
        assert_eq!(eng.metrics().preemptions, 0);
        assert_eq!(eng.request(i1).unwrap().state, State::Queued);
        let m = eng.run_to_completion().unwrap();
        assert_eq!(m.completed, 3);
        assert_eq!(m.preemptions, 0);
    }

    #[test]
    fn sim_engine_serves_and_reports_metrics() {
        let mut eng = EngineBuilder::sim()
            .max_batch(4)
            .ctx_limit(128)
            .build()
            .unwrap();
        let mut ids = vec![];
        for i in 0..6 {
            ids.push(eng.submit(vec![10 + i, 20, 30], 5).unwrap());
        }
        let m = eng.run_to_completion().unwrap();
        assert_eq!(m.backend, "sim");
        assert_eq!(m.completed, 6);
        assert_eq!(m.tokens_out, 6 * (5 - 1));
        assert_eq!(m.ttft_ms.count, 6);
        assert!(m.ttft_ms.p50 > 0.0 && m.ttft_ms.p50 <= m.ttft_ms.p95);
        assert!(m.ttft_ms.p95 <= m.ttft_ms.p99);
        assert!(m.per_token_ms.count == 6 && m.per_token_ms.mean > 0.0);
        assert!(m.wall_ms > 0.0);
        for id in ids {
            let st = eng.poll(id).unwrap();
            assert!(st.finished);
            assert_eq!(st.tokens_generated, 5);
        }
        // all KV reservations released
        assert_eq!(eng.kv_entries(), 0);
        assert_eq!(eng.pool_used_bytes(), 0);
    }

    #[test]
    fn prefix_cache_hits_skip_prefill_compute() {
        let mk = |cache: bool| {
            EngineBuilder::sim()
                .model("tiny-1M")
                .ctx_limit(128)
                .max_batch(2)
                .prefix_cache(cache)
                .build()
                .unwrap()
        };
        let prompt: Vec<i32> = (0..40).map(|i| (i % 200) as i32).collect();
        // cache on: the second identical prompt adopts the first one's
        // full prompt pages (2 pages = 32 tokens) and prefills only
        // the 8-token suffix
        let mut on = mk(true);
        let a = on.submit(prompt.clone(), 4).unwrap();
        let b = on.submit(prompt.clone(), 4).unwrap();
        let mon = on.run_to_completion().unwrap();
        assert_eq!(mon.completed, 2);
        assert_eq!(mon.prefix_hits, 1);
        assert_eq!(mon.prefix_tokens_saved, 32);
        assert_eq!(on.request(a).unwrap().cached_prefix_tokens, 0);
        assert_eq!(on.request(b).unwrap().cached_prefix_tokens, 32);
        // live reservations released; the cached prefix pages remain
        // reclaimable for the next hit
        assert_eq!(on.kv_entries(), 0);
        assert_eq!(on.pool_used_bytes(), 0);
        assert!(on.pool_cached_bytes() > 0);
        // cache off: same load, every prompt pays full prefill
        let mut off = mk(false);
        off.submit(prompt.clone(), 4).unwrap();
        off.submit(prompt, 4).unwrap();
        let moff = off.run_to_completion().unwrap();
        assert_eq!(moff.completed, 2);
        assert_eq!(moff.prefix_hits, 0);
        assert_eq!(moff.prefix_tokens_saved, 0);
        assert_eq!(off.pool_cached_bytes(), 0);
        assert!(
            mon.prefill_ms < moff.prefill_ms,
            "cached prefill {} !< cold prefill {}",
            mon.prefill_ms,
            moff.prefill_ms
        );
    }

    #[test]
    fn prefix_cache_survives_request_retirement() {
        let mut eng = EngineBuilder::sim()
            .model("tiny-1M")
            .ctx_limit(128)
            .max_batch(1)
            .build()
            .unwrap();
        let prompt: Vec<i32> = (0..33).map(|i| i as i32).collect();
        // serve to completion, then resubmit the same prompt: the hit
        // comes from pages that outlived the first request
        eng.submit(prompt.clone(), 3).unwrap();
        eng.run_to_completion().unwrap();
        assert_eq!(eng.kv_entries(), 0);
        let id = eng.submit(prompt, 3).unwrap();
        let m = eng.run_to_completion().unwrap();
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.prefix_tokens_saved, 32);
        let st = eng.poll(id).unwrap();
        assert!(st.finished);
        assert_eq!(st.tokens_generated, 3);
    }

    #[test]
    fn submit_rejects_bad_prompts() {
        let mut eng = EngineBuilder::sim().ctx_limit(16).build().unwrap();
        assert!(matches!(eng.submit(vec![], 4), Err(P3Error::EmptyPrompt)));
        match eng.submit(vec![1; 16], 4) {
            Err(P3Error::PromptTooLong { len, max }) => {
                assert_eq!(len, 16);
                assert_eq!(max, 15); // ctx_limit - 1: one decode slot
            }
            other => panic!("expected PromptTooLong, got {other:?}"),
        }
        assert!(eng.submit(vec![1; 15], 1).is_ok());
        assert!(eng.run_to_completion().is_ok());
    }

    /// Tiered engine over a working set that overflows the hot tier:
    /// a full-size hot tier is timing-identical to the single-tier
    /// engine, demand paging pays migration stalls, and the
    /// ahead-of-decode prefetcher strictly reduces both the stall
    /// count and the mean decode TPOT on the identical workload.
    #[test]
    fn tiered_kv_prefetch_strictly_beats_demand_paging() {
        let model = crate::config::llm::TINY;
        let layout = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: 160,
        };
        let per_req = layout.bytes_per_request();
        let mk = |hot: Option<(f64, usize)>| {
            let mut b = EngineBuilder::sim()
                .model("tiny-1M")
                .ctx_limit(160)
                .max_batch(2)
                .kv_capacity(per_req * 2);
            if let Some((f, depth)) = hot {
                b = b.hot_fraction(f).prefetch_depth(depth);
            }
            b.build().unwrap()
        };
        let run = |mut eng: Engine| {
            for i in 0..2i32 {
                eng.submit(vec![5 + i; 120], 30).unwrap();
            }
            let m = eng.run_to_completion().unwrap();
            assert_eq!(eng.kv_entries(), 0);
            if let Some((hot, cold, _)) = eng.tier_occupancy() {
                assert_eq!((hot, cold), (0, 0), "tier overlay leaked");
            }
            m
        };
        let base = run(mk(None));
        // hot tier == whole pool: no page ever leaves HBM, and the
        // timeline is bit-identical to the single-tier engine
        let full = run(mk(Some((1.0, 0))));
        assert_eq!(full.pages_prefetched + full.pages_demand, 0);
        assert_eq!(full.wall_ms, base.wall_ms);
        assert_eq!(full.per_token_ms, base.per_token_ms);
        // hot tier a quarter of the pool: both lanes' attention
        // windows (10 pages each) overflow the 5-page cap every step
        let demand = run(mk(Some((0.25, 0))));
        let prefetch = run(mk(Some((0.25, 4))));
        assert_eq!(demand.completed, 2);
        assert_eq!(prefetch.completed, 2);
        assert!(demand.pages_demand > 0, "{demand:?}");
        assert_eq!(demand.pages_prefetched, 0);
        assert!(prefetch.pages_prefetched > 0, "{prefetch:?}");
        assert!(
            prefetch.pages_demand < demand.pages_demand,
            "prefetch converted no demand misses: {} !< {}",
            prefetch.pages_demand,
            demand.pages_demand
        );
        // the decode step sequence is identical (same admissions, same
        // sim costs); only the demand stalls differ, so the TPOT win
        // is strict, and any migration traffic costs wall clock over
        // the single-tier baseline
        assert!(
            prefetch.per_token_ms.mean < demand.per_token_ms.mean,
            "prefetch-on TPOT {} !< demand-paging TPOT {}",
            prefetch.per_token_ms.mean,
            demand.per_token_ms.mean
        );
        assert!(demand.wall_ms > base.wall_ms);
    }

    /// Satellite of `mem::tier`'s residency-conservation property:
    /// the same invariants under real engine churn -- randomized SLO
    /// classes, shared prefixes, preemption (swap and recompute) and
    /// retirement over a tiered pool.  Every request finishes with its
    /// full budget and both the pool and the residency overlay drain
    /// to empty.
    #[test]
    fn property_tiered_churn_conserves_pages_and_requests() {
        use crate::testutil::{Rng, Runner};
        let model = crate::config::llm::TINY;
        let layout = KvLayout {
            layers: model.layers,
            kv_dim: model.kv_dim(),
            head_dim: model.head_dim,
            max_ctx: 128,
        };
        let per_req = layout.bytes_per_request();
        Runner::new(8).run(|rng: &mut Rng| {
            let mut eng = EngineBuilder::sim()
                .model("tiny-1M")
                .ctx_limit(128)
                .max_batch(4)
                .kv_capacity(per_req * 2)
                .preempt(if rng.bool() { "swap" } else { "recompute" })
                .aging_ms(f64::INFINITY)
                .hot_fraction(0.2 + 0.6 * rng.f64())
                .prefetch_depth(rng.usize(0, 5))
                .build()
                .unwrap();
            let shared: Vec<i32> = (0..32).collect();
            let mut ids = vec![];
            let n = rng.usize(4, 10);
            for k in 0..n {
                let mut prompt = if rng.bool() {
                    shared.clone()
                } else {
                    vec![60 + k as i32; rng.usize(8, 40)]
                };
                if rng.bool() {
                    let ext = rng.usize(1, 30);
                    prompt.extend((0..ext).map(|j| 100 + j as i32));
                }
                let class = *rng.pick(&crate::sched::SloClass::all());
                let max_new = rng.usize(1, 24);
                ids.push(eng.submit_class(prompt, max_new, class).unwrap());
                if rng.bool() {
                    eng.step().unwrap();
                }
                // the overlay's own invariants hold mid-churn
                eng.tier.as_ref().unwrap().tier.check_invariants();
            }
            let m = eng.run_to_completion().unwrap();
            assert_eq!(m.completed, ids.len());
            let (mut pre, mut dem) = (0usize, 0usize);
            for id in &ids {
                let st = eng.poll(*id).unwrap();
                assert!(st.finished, "{id:?} did not finish");
                let r = eng.request(*id).unwrap();
                pre += r.pages_prefetched;
                dem += r.pages_demand;
            }
            // per-request counters telescope to the engine totals
            assert_eq!(m.pages_prefetched, pre);
            assert_eq!(m.pages_demand, dem);
            // pool and overlay both drain: no page left in any tier
            assert_eq!(eng.kv_entries(), 0);
            assert_eq!(eng.pool_used_bytes(), 0);
            let ts = eng.tier.as_ref().unwrap();
            ts.tier.check_invariants();
            let (hot, cold, _) = eng.tier_occupancy().unwrap();
            assert_eq!((hot, cold), (0, 0), "residency overlay leaked");
        });
    }
}
