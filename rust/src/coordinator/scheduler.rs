//! Serving-level timeline simulation: Poisson arrivals, prefill/decode
//! interleaving, TTFT / per-token latency distributions under each
//! modeled accelerator.  This is the coordinator-policy view the edge
//! scenarios of Section I imply (chatbot interaction with a
//! time-to-first-token SLO, cf. the 250 ms DistServe reference the
//! paper cites for its smoothing-overhead budget).

use crate::accel::Accel;
use crate::config::llm::LlmConfig;
use crate::sim::npu;
use crate::workload::{prefill_trace, Op};

#[derive(Debug, Clone)]
pub struct ServingParams {
    /// mean request inter-arrival (ms)
    pub interarrival_ms: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub n_requests: usize,
    pub max_batch: usize,
    /// context length used for decode-step costing
    pub ctx: usize,
}

impl Default for ServingParams {
    fn default() -> Self {
        ServingParams {
            interarrival_ms: 150.0,
            prompt_tokens: 512,
            output_tokens: 128,
            n_requests: 32,
            max_batch: 8,
            ctx: 4096,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ServingReport {
    pub mean_ttft_ms: f64,
    pub p95_ttft_ms: f64,
    pub mean_tpot_ms: f64,
    pub throughput_tok_s: f64,
    pub makespan_ms: f64,
    /// fraction of requests meeting a 250 ms TTFT SLO
    pub slo_250ms: f64,
}

/// Prefill latency of one request on the NPU (prefill is always NPU
/// territory -- compute-bound GEMM, Section II).
pub fn prefill_ms(accel: &Accel, model: &LlmConfig, n_tokens: usize) -> f64 {
    let mut ns = 0.0;
    for op in prefill_trace(model, 1, n_tokens) {
        ns += match &op {
            Op::Vector { elems, .. } => {
                npu::vector(&accel.system.npu, *elems).ns
            }
            Op::Gemm { .. } => accel.npu_cost_pub(&op).ns,
        };
    }
    ns / 1e6
}

/// Deterministic-seed Poisson-ish arrival simulation with continuous
/// batching: decode proceeds in steps over the active set; new
/// requests join at step boundaries after their (serialized) prefill.
pub fn simulate(
    accel: &Accel,
    model: &LlmConfig,
    p: &ServingParams,
    seed: u64,
) -> ServingReport {
    let mut rng = crate::testutil::Rng::new(seed);
    // exponential inter-arrivals
    let mut arrivals = Vec::with_capacity(p.n_requests);
    let mut t = 0.0f64;
    for _ in 0..p.n_requests {
        let u = (rng.f32() as f64).max(1e-6);
        t += -p.interarrival_ms * u.ln();
        arrivals.push(t);
    }
    let pre_ms = prefill_ms(accel, model, p.prompt_tokens);

    #[derive(Clone)]
    struct R {
        arrival: f64,
        first_token: Option<f64>,
        remaining: usize,
        done_at: f64,
    }
    let mut reqs: Vec<R> = arrivals
        .iter()
        .map(|&a| R {
            arrival: a,
            first_token: None,
            remaining: p.output_tokens,
            done_at: 0.0,
        })
        .collect();

    let mut now = 0.0f64;
    let mut active: Vec<usize> = vec![];
    let mut next = 0usize;
    let mut tokens_done = 0usize;
    while tokens_done < p.n_requests * p.output_tokens {
        // admit arrived requests (serialized prefill on the NPU)
        while next < reqs.len()
            && reqs[next].arrival <= now
            && active.len() < p.max_batch
        {
            now = now.max(reqs[next].arrival) + pre_ms;
            reqs[next].first_token = Some(now);
            reqs[next].remaining -= 1;
            tokens_done += 1;
            active.push(next);
            next += 1;
        }
        if active.is_empty() {
            if next < reqs.len() {
                now = reqs[next].arrival;
                continue;
            }
            break;
        }
        // one decode step over the active batch
        let bs = active.len();
        let step_ms =
            accel.decode_step(model, bs, p.ctx).total_ns() / 1e6;
        now += step_ms;
        let mut still = vec![];
        for &i in &active {
            reqs[i].remaining -= 1;
            tokens_done += 1;
            if reqs[i].remaining == 0 {
                reqs[i].done_at = now;
            } else {
                still.push(i);
            }
        }
        active = still;
    }

    let mut ttfts: Vec<f64> = reqs
        .iter()
        .filter_map(|r| r.first_token.map(|f| f - r.arrival))
        .collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = ttfts.len().max(1);
    let mean_ttft = ttfts.iter().sum::<f64>() / n as f64;
    let p95 = ttfts[(n * 95 / 100).min(n - 1)];
    let makespan = reqs
        .iter()
        .map(|r| r.done_at)
        .fold(0.0f64, f64::max)
        .max(now);
    let total_tokens = (p.n_requests * p.output_tokens) as f64;
    ServingReport {
        mean_ttft_ms: mean_ttft,
        p95_ttft_ms: p95,
        mean_tpot_ms: makespan / total_tokens,
        throughput_tok_s: total_tokens / (makespan / 1e3),
        makespan_ms: makespan,
        slo_250ms: ttfts.iter().filter(|&&t| t <= 250.0).count() as f64
            / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llm::LLAMA32_3B;
    use crate::testutil::Runner;

    #[test]
    fn p3_beats_npu_on_throughput_and_ttft() {
        let p = ServingParams { n_requests: 16, ..Default::default() };
        let m = &LLAMA32_3B;
        let npu = simulate(&Accel::npu_fp16(), m, &p, 1);
        let p3 = simulate(&Accel::p3llm(), m, &p, 1);
        assert!(p3.throughput_tok_s > npu.throughput_tok_s);
        assert!(p3.mean_ttft_ms <= npu.mean_ttft_ms * 1.01);
    }

    #[test]
    fn all_tokens_accounted() {
        Runner::new(8).run(|r| {
            let p = ServingParams {
                n_requests: r.usize(2, 12),
                output_tokens: r.usize(4, 40),
                interarrival_ms: r.range_f32(10.0, 400.0) as f64,
                ..Default::default()
            };
            let rep = simulate(&Accel::p3llm(), &LLAMA32_3B, &p, r.next_u64());
            // throughput * makespan == total tokens (conservation)
            let tokens = rep.throughput_tok_s * rep.makespan_ms / 1e3;
            let want = (p.n_requests * p.output_tokens) as f64;
            assert!((tokens - want).abs() < 1.0, "{tokens} vs {want}");
            assert!(rep.mean_ttft_ms >= 0.0);
            assert!(rep.p95_ttft_ms >= rep.mean_ttft_ms * 0.5);
        });
    }

    #[test]
    fn saturation_raises_ttft() {
        let m = &LLAMA32_3B;
        let slow = ServingParams { interarrival_ms: 1.0, ..Default::default() };
        let calm = ServingParams {
            interarrival_ms: 5000.0,
            ..Default::default()
        };
        let a = simulate(&Accel::hbm_pim(), m, &slow, 3);
        let b = simulate(&Accel::hbm_pim(), m, &calm, 3);
        assert!(a.mean_ttft_ms > b.mean_ttft_ms);
    }
}
