//! The replica fleet: N serving engines behind one router on a shared
//! virtual timeline.
//!
//! Every replica is a full [`Engine`] (sim backend: batcher, KV pool,
//! cost-model clock).  The cluster advances them lock-step -- each
//! [`step`](LoadTarget::step) runs the busy replica whose local clock
//! is furthest behind, and idle replicas fast-forward through
//! [`ExecBackend::advance_to`](crate::coordinator::ExecBackend::advance_to)
//! when the runner jumps over arrival gaps -- so the fleet shares one
//! causal virtual clock and whole runs stay bit-identical under a
//! seed.
//!
//! Routing is pluggable ([`RoutePolicy`]).  Colocated policies place
//! each request on one replica; the prefill/decode-disaggregated
//! policy runs the prompt on a prefill replica, then hands the
//! finished KV to a decode replica *through the shared CXL cold
//! pool* -- the prefill side writes the prompt KV out, the decode side
//! reads it back, two link passes priced by the unified slow-tier
//! transfer model in [`crate::mem::transfer`] (each pass is the max of
//! the HBM streaming pass and the CXL link time).

use crate::accel;
use crate::config::accel::HbmTiming;
use crate::config::cxl::CxlLink;
use crate::coordinator::{
    prefix_page_hash, Engine, Metrics, Percentiles, RequestId,
};
use crate::error::{P3Error, Result};
use crate::obs::Obs;
use crate::sched::SloClass;
use crate::telemetry::Trace;
use crate::traffic::{
    LoadReport, LoadRunner, LoadTarget, ReqRecord, RunOutcome, Scenario,
};

use super::policy::{policy_by_name, ReplicaSnapshot, RoutePolicy, RouteQuery};
use super::report::ClusterReport;

/// One routed request's lifecycle across the fleet.
#[derive(Debug)]
struct Ticket {
    prefill_replica: usize,
    prefill_id: RequestId,
    /// total output budget across both phases
    max_new: usize,
    /// SLO tier the client submitted under (carried to both phases)
    class: SloClass,
    /// decode-side continuation, once handed off (disaggregated: the
    /// prefill side ran with `max_new = 1` and the rest decodes here)
    decode: Option<(usize, RequestId)>,
}

/// A cluster run's results: the exact fleet-level [`RunOutcome`]
/// (merged per-request records) plus the merged per-replica
/// [`ClusterReport`] view.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    pub run: RunOutcome,
    pub report: ClusterReport,
}

pub struct Cluster {
    replicas: Vec<Engine>,
    policy: Box<dyn RoutePolicy>,
    /// HBM timing of the modeled system: prices inter-replica KV
    /// handoffs (disaggregated routing)
    hbm: HbmTiming,
    /// CXL link of the shared cold pool the `pd` handoff rides
    cxl: CxlLink,
    tickets: Vec<Ticket>,
    /// ticket indices whose prefill side has not handed off yet
    open_handoffs: Vec<usize>,
    /// a cluster is single-use: replica metrics and tickets accumulate
    /// across runs, so a second run would misattribute everything
    ran: bool,
}

impl Cluster {
    /// Wrap `engines` (all serving the same model) behind `policy`.
    /// `hbm` prices KV handoffs for disaggregated policies.
    pub fn new(
        engines: Vec<Engine>,
        policy: Box<dyn RoutePolicy>,
        hbm: HbmTiming,
    ) -> Result<Self> {
        if engines.is_empty() {
            return Err(P3Error::InvalidConfig(
                "a cluster needs at least one replica".into(),
            ));
        }
        let model = engines[0].model().name;
        if engines.iter().any(|e| e.model().name != model) {
            return Err(P3Error::InvalidConfig(
                "all cluster replicas must serve the same model".into(),
            ));
        }
        if engines.iter().any(|e| e.backend_name() == "pjrt") {
            return Err(P3Error::InvalidConfig(
                "cluster replicas must run the sim backend (a wall \
                 clock cannot be lock-stepped across replicas)"
                    .into(),
            ));
        }
        Ok(Cluster {
            replicas: engines,
            policy,
            hbm,
            cxl: CxlLink::default(),
            tickets: vec![],
            open_handoffs: vec![],
            ran: false,
        })
    }

    /// `replicas` identically-shaped engines for `scenario` on the
    /// named system, routed by `policy_name` (see
    /// [`all_policy_names`](super::policy::all_policy_names)).
    pub fn from_scenario(
        scenario: &Scenario,
        system: &str,
        scheme: Option<&str>,
        replicas: usize,
        policy_name: &str,
    ) -> Result<Self> {
        let policy = policy_by_name(policy_name).ok_or_else(|| {
            P3Error::InvalidConfig(format!(
                "unknown routing policy {policy_name:?} \
                 (rr | jsq | kv | pa | pd)"
            ))
        })?;
        // replicas == 0 falls through to Cluster::new's typed
        // at-least-one-replica rejection rather than a silent clamp
        let mut engines = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            engines.push(scenario.engine(system, scheme)?);
        }
        let hbm = accel::by_name(system)
            .ok_or_else(|| P3Error::UnknownSystem(system.into()))?
            .system
            .hbm;
        Cluster::new(engines, policy, hbm)
    }

    /// [`Cluster::from_scenario`] with telemetry: replica `i` records
    /// into [`trace.for_replica(i)`](Trace::for_replica), so the whole
    /// fleet shares one sink and its streams merge by construction --
    /// every event carries its replica tag, and one export renders one
    /// Perfetto track group per replica.
    pub fn from_scenario_traced(
        scenario: &Scenario,
        system: &str,
        scheme: Option<&str>,
        replicas: usize,
        policy_name: &str,
        trace: &Trace,
    ) -> Result<Self> {
        let mut c = Cluster::from_scenario(
            scenario, system, scheme, replicas, policy_name,
        )?;
        for (i, r) in c.replicas.iter_mut().enumerate() {
            r.set_trace(trace.for_replica(i as u32));
        }
        Ok(c)
    }

    /// [`Cluster::from_scenario_traced`] plus observability: replica
    /// `i` samples into [`obs.for_replica(i)`](Obs::for_replica), so
    /// the fleet shares one metrics hub -- per-replica series carry
    /// their replica tag, fleet rollups (burn-rate alerting, the
    /// health report's replica skew) merge across tags by
    /// construction, and the shared scrape clock samples the whole
    /// fleet at one cadence.
    pub fn from_scenario_observed(
        scenario: &Scenario,
        system: &str,
        scheme: Option<&str>,
        replicas: usize,
        policy_name: &str,
        trace: &Trace,
        obs: &Obs,
    ) -> Result<Self> {
        let mut c = Cluster::from_scenario_traced(
            scenario, system, scheme, replicas, policy_name, trace,
        )?;
        for (i, r) in c.replicas.iter_mut().enumerate() {
            r.set_obs(obs.for_replica(i as u32));
        }
        Ok(c)
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// End-of-run engine metrics of one replica.
    pub fn replica_metrics(&self, i: usize) -> Metrics {
        self.replicas[i].metrics()
    }

    /// Borrow one replica engine (tests / inspection).
    pub fn replica(&self, i: usize) -> &Engine {
        &self.replicas[i]
    }

    fn snapshots(&self, pool: &[usize]) -> Vec<ReplicaSnapshot> {
        pool.iter()
            .map(|&i| {
                let r = &self.replicas[i];
                ReplicaSnapshot {
                    index: i,
                    queued: r.queued(),
                    active: r.active_lanes(),
                    kv_used_bytes: r.pool_used_bytes(),
                    now_ms: Engine::now_ms(r),
                }
            })
            .collect()
    }

    /// Modeled KV handoff time for `tokens` cached tokens moving from
    /// the prefill replica to the decode replica *through the shared
    /// CXL cold pool* (no replica-to-replica bus copy): the prefill
    /// side writes the packed KV out and the decode side reads it
    /// back, two link passes priced by
    /// [`crate::mem::pool_handoff_ms`].
    ///
    /// Priced on the *exact* packed bytes (2 sides x layers x tokens x
    /// kv_dim/2), not the page-rounded `bytes_per_request` sizing
    /// helper -- only occupied token slots cross the fabric.
    pub fn kv_transfer_ms(&self, tokens: usize) -> f64 {
        let m = self.replicas[0].model();
        crate::mem::pool_handoff_ms(&self.hbm, &self.cxl, m, tokens)
    }

    /// Hand off every finished prefill on `replica` to a decode
    /// replica (disaggregated policies only).
    fn drain_handoffs(&mut self, replica: usize) -> Result<()> {
        let mut ready = vec![];
        let tickets = &self.tickets;
        let replicas = &self.replicas;
        self.open_handoffs.retain(|&ti| {
            let t = &tickets[ti];
            if t.prefill_replica != replica {
                return true;
            }
            match replicas[replica].request(t.prefill_id) {
                Some(req) if req.finished_ms.is_some() => {
                    ready.push(ti);
                    false
                }
                _ => true,
            }
        });
        for ti in ready {
            let (pid, pre, total, class) = {
                let t = &self.tickets[ti];
                (t.prefill_id, t.prefill_replica, t.max_new, t.class)
            };
            let (handoff_at, cont_prompt) = {
                let req = self.replicas[pre]
                    .request(pid)
                    .ok_or(P3Error::UnknownRequest(pid.0))?;
                let mut p = req.prompt.clone();
                p.extend_from_slice(&req.generated);
                (req.finished_ms.unwrap_or(0.0), p)
            };
            let transfer_ms = self.kv_transfer_ms(cont_prompt.len());
            let pool = self
                .policy
                .decode_pool(self.replicas.len())
                .ok_or_else(|| {
                    P3Error::Serve(
                        "split ticket without a decode pool".into(),
                    )
                })?;
            let snaps = self.snapshots(&pool);
            let dq = RouteQuery {
                prompt_len: cont_prompt.len(),
                max_new: total - 1,
                affinity: prefix_page_hash(&cont_prompt),
                class,
            };
            let d = self.policy.route_decode(&dq, &snaps);
            // causality: the KV cannot land before the prefill that
            // produced it finished.  The decode replica synchronizes
            // on the fabric barrier even if its local clock lags (its
            // in-flight lanes are billed the sync gap); without this a
            // lagging replica could finish the continuation before
            // its own first token existed, inflating pd SLO numbers
            // with acausal timelines.
            self.replicas[d].advance_clock_to(handoff_at);
            let id = self.replicas[d].submit_prefilled_class(
                cont_prompt,
                total - 1,
                transfer_ms,
                class,
            )?;
            self.tickets[ti].decode = Some((d, id));
        }
        Ok(())
    }
}

impl LoadTarget for Cluster {
    /// The fleet's causal frontier: the earliest clock among busy
    /// replicas (they can still do work at that time); when everything
    /// is idle, the furthest clock any replica reached.
    fn now_ms(&self) -> f64 {
        let mut busy_min = f64::INFINITY;
        let mut all_max = 0.0f64;
        for r in &self.replicas {
            let t = Engine::now_ms(r);
            all_max = all_max.max(t);
            if !Engine::is_idle(r) {
                busy_min = busy_min.min(t);
            }
        }
        if busy_min.is_finite() {
            busy_min
        } else {
            all_max
        }
    }

    fn is_idle(&self) -> bool {
        self.replicas.iter().all(Engine::is_idle)
            && self.open_handoffs.is_empty()
    }

    fn advance_clock_to(&mut self, ms: f64) {
        for r in &mut self.replicas {
            if Engine::is_idle(r) {
                r.advance_clock_to(ms);
            }
        }
    }

    fn max_prompt(&self) -> usize {
        self.replicas
            .iter()
            .map(Engine::max_prompt)
            .min()
            .unwrap_or(1)
    }

    fn vocab(&self) -> usize {
        self.replicas[0].model().vocab
    }

    fn submit(
        &mut self,
        prompt: Vec<i32>,
        max_new: usize,
        due_ms: f64,
        class: SloClass,
    ) -> Result<u64> {
        let n = self.replicas.len();
        let pool = self.policy.prefill_pool(n);
        let snaps = self.snapshots(&pool);
        let query = RouteQuery {
            prompt_len: prompt.len(),
            max_new,
            affinity: prefix_page_hash(&prompt),
            class,
        };
        let chosen = self.policy.route(&query, &snaps);
        // disaggregate only when there is a decode pool, something
        // left to decode, and the continuation (prompt + first token)
        // still fits a decode replica's context
        let split = self.policy.decode_pool(n).is_some()
            && max_new > 1
            && prompt.len() + 1 <= LoadTarget::max_prompt(self);
        if self.replicas[chosen].is_idle() {
            self.replicas[chosen].advance_clock_to(due_ms);
        }
        let pf_new = if split { 1 } else { max_new };
        let id =
            self.replicas[chosen].submit_class(prompt, pf_new, class)?;
        let ticket = self.tickets.len() as u64;
        if split {
            self.open_handoffs.push(self.tickets.len());
        }
        self.tickets.push(Ticket {
            prefill_replica: chosen,
            prefill_id: id,
            max_new,
            class,
            decode: None,
        });
        Ok(ticket)
    }

    /// Advance the laggard: step the busy replica whose clock is
    /// furthest behind, then hand off any prefill it just finished.
    fn step(&mut self) -> Result<()> {
        let mut pick: Option<(usize, f64)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if !Engine::is_idle(r) {
                let t = Engine::now_ms(r);
                if pick.map_or(true, |(_, bt)| t < bt) {
                    pick = Some((i, t));
                }
            }
        }
        match pick {
            Some((i, _)) => {
                self.replicas[i].step()?;
                self.drain_handoffs(i)
            }
            None => {
                // nothing busy: flush any straggler handoffs so the
                // run loop cannot stall
                for i in 0..self.replicas.len() {
                    self.drain_handoffs(i)?;
                }
                Ok(())
            }
        }
    }

    fn record(
        &self,
        ticket: u64,
        scheduled_arrival_ms: f64,
    ) -> Result<ReqRecord> {
        let t = self
            .tickets
            .get(ticket as usize)
            .ok_or(P3Error::UnknownRequest(ticket))?;
        let pre = self.replicas[t.prefill_replica]
            .request(t.prefill_id)
            .ok_or(P3Error::UnknownRequest(t.prefill_id.0))?;
        let mut rec = ReqRecord::from_request(pre, scheduled_arrival_ms);
        if let Some((d, id)) = t.decode {
            // client view of a disaggregated request: first token from
            // the prefill side, completion (and the transfer gap) from
            // the decode side
            let dec = self.replicas[d]
                .request(id)
                .ok_or(P3Error::UnknownRequest(id.0))?;
            rec.finished_ms = dec.finished_ms;
            rec.tokens_generated =
                pre.generated.len() + dec.generated.len();
            // preemption and tier churn can hit either phase
            rec.preemptions += dec.preemptions;
            rec.pages_swapped += dec.pages_swapped;
            rec.pages_recomputed += dec.pages_recomputed;
            rec.pages_prefetched += dec.pages_prefetched;
            rec.pages_demand += dec.pages_demand;
        }
        Ok(rec)
    }

    /// Fleet-merged *engine-level* metrics: counters sum, the clock is
    /// the furthest replica, distributions merge count-weighted
    /// ([`Percentiles::merge`]).  Under a disaggregated policy each
    /// client request is two engine requests (prefill stub + decode
    /// continuation), so `completed` counts both and the latency
    /// distributions are engine-side observations -- the client-level
    /// view is the record-based [`LoadReport`] a run produces.
    fn end_metrics(&self) -> Metrics {
        let per: Vec<Metrics> =
            self.replicas.iter().map(|r| r.metrics()).collect();
        let ttfts: Vec<&Percentiles> =
            per.iter().map(|m| &m.ttft_ms).collect();
        let tpots: Vec<&Percentiles> =
            per.iter().map(|m| &m.per_token_ms).collect();
        Metrics {
            backend: "cluster",
            completed: per.iter().map(|m| m.completed).sum(),
            decode_steps: per.iter().map(|m| m.decode_steps).sum(),
            tokens_out: per.iter().map(|m| m.tokens_out).sum(),
            wall_ms: per.iter().map(|m| m.wall_ms).fold(0.0, f64::max),
            prefill_ms: per.iter().map(|m| m.prefill_ms).sum(),
            decode_ms: per.iter().map(|m| m.decode_ms).sum(),
            prefix_hits: per.iter().map(|m| m.prefix_hits).sum(),
            prefix_tokens_saved: per
                .iter()
                .map(|m| m.prefix_tokens_saved)
                .sum(),
            preemptions: per.iter().map(|m| m.preemptions).sum(),
            pages_swapped: per.iter().map(|m| m.pages_swapped).sum(),
            pages_recomputed: per
                .iter()
                .map(|m| m.pages_recomputed)
                .sum(),
            pages_prefetched: per
                .iter()
                .map(|m| m.pages_prefetched)
                .sum(),
            pages_demand: per.iter().map(|m| m.pages_demand).sum(),
            npu_busy_ms: per.iter().map(|m| m.npu_busy_ms).sum(),
            pim_busy_ms: per.iter().map(|m| m.pim_busy_ms).sum(),
            overlap_ms: per.iter().map(|m| m.overlap_ms).sum(),
            interleaved_steps: per
                .iter()
                .map(|m| m.interleaved_steps)
                .sum(),
            fused_steps: per.iter().map(|m| m.fused_steps).sum(),
            serial_saved_ms: per.iter().map(|m| m.serial_saved_ms).sum(),
            ttft_ms: Percentiles::merge(&ttfts),
            per_token_ms: Percentiles::merge(&tpots),
        }
    }
}

impl Cluster {
    /// Drive `plan` through the fleet to completion and report: the
    /// exact fleet-level outcome plus the merged per-replica view.
    /// `saturation_per_replica` is one replica's modeled peak decode
    /// rate (the fleet roof is `replicas x` that).  One run per
    /// cluster: replicas keep their retired requests for the records.
    pub fn run(
        &mut self,
        plan: &LoadRunner,
        saturation_per_replica: Option<f64>,
    ) -> Result<ClusterOutcome> {
        if self.ran {
            return Err(P3Error::Serve(
                "a Cluster is single-use: replica metrics and routing \
                 tickets accumulate across runs, so a second run would \
                 misattribute every record -- build a fresh cluster"
                    .into(),
            ));
        }
        self.ran = true;
        let mut run = plan.run(self)?;
        let n = self.replicas.len();
        run.report.saturation_tok_s =
            saturation_per_replica.map(|s| s * n as f64);
        // snapshot each replica's metrics once (Percentiles sort the
        // full sample vectors on every call)
        let per_metrics: Vec<Metrics> =
            self.replicas.iter().map(|r| r.metrics()).collect();
        // fleet-aggregate decode service rate in use (sum of
        // per-replica busy rates), matching ClusterReport::merge and
        // the n-scaled saturation roof above -- the engines' summed
        // Metrics would otherwise report the per-replica *average*
        run.report.busy_tok_s =
            per_metrics.iter().map(|m| m.tokens_per_sec()).sum::<f64>();
        // partition the merged records by the replica that *finished*
        // each request (decode side for disaggregated tickets)
        let mut parts: Vec<Vec<ReqRecord>> = vec![vec![]; n];
        for (i, rec) in run.records.iter().enumerate() {
            let t = &self.tickets[i];
            let owner = t.decode.map(|(d, _)| d).unwrap_or(t.prefill_replica);
            parts[owner].push(*rec);
        }
        let per: Vec<LoadReport> = parts
            .iter()
            .zip(per_metrics.iter())
            .map(|(recs, m)| {
                LoadReport::from_records(
                    recs,
                    &plan.slo,
                    m,
                    saturation_per_replica,
                )
            })
            .collect();
        let busy_ms: Vec<f64> = per_metrics
            .iter()
            .map(|m| m.prefill_ms + m.decode_ms)
            .collect();
        // rates rebase onto the exact fleet span from the merged
        // records, not the max per-replica window
        let report = ClusterReport::merge(
            self.policy.name(),
            &per,
            &busy_ms,
            Some(run.report.makespan_ms),
        );
        Ok(ClusterOutcome { run, report })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::scenario_by_name;

    #[test]
    fn construction_validates_shape() {
        let sc = scenario_by_name("smoke").unwrap();
        assert!(Cluster::from_scenario(&sc, "P3-LLM", None, 2, "jsq").is_ok());
        assert!(matches!(
            Cluster::from_scenario(&sc, "P3-LLM", None, 2, "nope"),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            Cluster::from_scenario(&sc, "no-such-system", None, 2, "jsq"),
            Err(P3Error::UnknownSystem(_))
        ));
        // zero replicas is a typed rejection, not a silent clamp
        assert!(matches!(
            Cluster::from_scenario(&sc, "P3-LLM", None, 0, "jsq"),
            Err(P3Error::InvalidConfig(_))
        ));
        assert!(matches!(
            Cluster::new(
                vec![],
                policy_by_name("rr").unwrap(),
                HbmTiming::default()
            ),
            Err(P3Error::InvalidConfig(_))
        ));
        let c = Cluster::from_scenario(&sc, "P3-LLM", None, 3, "pd").unwrap();
        assert_eq!(c.replicas(), 3);
        assert_eq!(c.policy_name(), "pd");
    }

    #[test]
    fn kv_transfer_cost_is_positive_and_monotone() {
        let sc = scenario_by_name("smoke").unwrap();
        let c = Cluster::from_scenario(&sc, "P3-LLM", None, 2, "pd").unwrap();
        let short = c.kv_transfer_ms(16);
        let long = c.kv_transfer_ms(1024);
        assert!(short > 0.0);
        assert!(long > short, "{long} vs {short}");
    }
}
