//! Fleet-level reporting: merge per-replica [`LoadReport`]s into one
//! cluster view -- fleet goodput, SLO attainment, per-replica
//! utilization skew, and scaling efficiency against a 1-replica
//! baseline.
//!
//! Counts sum exactly; rates are re-based token-exactly onto the fleet
//! makespan (the longest per-replica span); latency distributions
//! merge count-weighted through
//! [`Percentiles::merge`](crate::coordinator::Percentiles::merge).

use crate::coordinator::Percentiles;
use crate::sched::SloClass;
use crate::traffic::LoadReport;

/// One replica's share of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaLoad {
    /// the requests this replica finished
    pub report: LoadReport,
    /// engine-busy milliseconds (prefill + decode): the utilization
    /// signal, which also credits prefill-only replicas of a
    /// disaggregated fleet
    pub busy_ms: f64,
}

/// Merged view of one cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub policy: String,
    pub replicas: usize,
    /// fleet totals merged from the per-replica reports
    pub fleet: LoadReport,
    pub per_replica: Vec<ReplicaLoad>,
    /// max / mean of per-replica busy time: 1.0 is a perfectly
    /// balanced fleet, `replicas` is one replica doing all the work
    pub util_skew: f64,
    /// fleet goodput / (replicas x 1-replica-baseline goodput); set by
    /// [`with_baseline`](Self::with_baseline) once a baseline is known
    pub scaling_efficiency: Option<f64>,
}

impl ClusterReport {
    /// Merge per-replica reports (`per[i]` holds the requests replica
    /// `i` finished; `busy_ms[i]` its engine-busy time).
    ///
    /// `fleet_makespan_ms` is the true fleet span (global first
    /// arrival to global last completion) when the caller knows it --
    /// per-replica makespans are *relative* windows, so falling back
    /// to their maximum (`None`) overstates fleet rates when replica
    /// activity windows are disjoint in time.
    pub fn merge(
        policy: &str,
        per: &[LoadReport],
        busy_ms: &[f64],
        fleet_makespan_ms: Option<f64>,
    ) -> Self {
        let n = per.len();
        let offered: usize = per.iter().map(|r| r.offered).sum();
        let completed: usize = per.iter().map(|r| r.completed).sum();
        let slo_met: usize = per.iter().map(|r| r.slo_met).sum();
        let prefix_hits: usize = per.iter().map(|r| r.prefix_hits).sum();
        let prefill_tokens_saved: usize =
            per.iter().map(|r| r.prefill_tokens_saved).sum();
        let makespan_ms = fleet_makespan_ms.unwrap_or_else(|| {
            per.iter().map(|r| r.makespan_ms).fold(0.0, f64::max)
        });
        // token-exact rate rebase: rate_i * makespan_i recovers each
        // replica's count, the fleet rate divides by the fleet span
        let rebase = |count_x_ms: f64| {
            if makespan_ms > 0.0 {
                count_x_ms / makespan_ms
            } else {
                0.0
            }
        };
        let queue_parts: Vec<&Percentiles> =
            per.iter().map(|r| &r.queue_delay_ms).collect();
        let ttft_parts: Vec<&Percentiles> =
            per.iter().map(|r| &r.ttft_ms).collect();
        let tpot_parts: Vec<&Percentiles> =
            per.iter().map(|r| &r.tpot_ms).collect();
        let saturation = if n > 0
            && per.iter().all(|r| r.saturation_tok_s.is_some())
        {
            Some(per.iter().filter_map(|r| r.saturation_tok_s).sum::<f64>())
        } else {
            None
        };
        // fleet per-tier rows: merge each tier's per-replica
        // sub-reports with the same rebase rules.  Sub-reports carry
        // empty `per_class` themselves, so the recursion is one level
        // deep.
        let mut per_class = vec![];
        for class in SloClass::all() {
            let parts: Vec<LoadReport> = per
                .iter()
                .flat_map(|r| {
                    r.per_class
                        .iter()
                        .filter(|(c, _)| *c == class)
                        .map(|(_, sub)| sub.clone())
                })
                .collect();
            if parts.is_empty() {
                continue;
            }
            let zeros = vec![0.0; parts.len()];
            let sub = ClusterReport::merge(
                policy,
                &parts,
                &zeros,
                Some(makespan_ms),
            );
            per_class.push((class, sub.fleet));
        }
        let fleet = LoadReport {
            offered,
            completed,
            slo_met,
            slo_attainment: if offered > 0 {
                slo_met as f64 / offered as f64
            } else {
                0.0
            },
            makespan_ms,
            throughput_tok_s: rebase(
                per.iter()
                    .map(|r| r.throughput_tok_s * r.makespan_ms)
                    .sum::<f64>(),
            ),
            goodput_req_s: rebase(
                per.iter()
                    .map(|r| r.goodput_req_s * r.makespan_ms)
                    .sum::<f64>(),
            ),
            goodput_tok_s: rebase(
                per.iter()
                    .map(|r| r.goodput_tok_s * r.makespan_ms)
                    .sum::<f64>(),
            ),
            // aggregate decode service rate in use across the fleet
            busy_tok_s: per.iter().map(|r| r.busy_tok_s).sum(),
            saturation_tok_s: saturation,
            prefix_hits,
            prefix_hit_rate: if offered > 0 {
                prefix_hits as f64 / offered as f64
            } else {
                0.0
            },
            prefill_tokens_saved,
            preemptions: per.iter().map(|r| r.preemptions).sum(),
            pages_swapped: per.iter().map(|r| r.pages_swapped).sum(),
            pages_recomputed: per
                .iter()
                .map(|r| r.pages_recomputed)
                .sum(),
            pages_prefetched: per
                .iter()
                .map(|r| r.pages_prefetched)
                .sum(),
            pages_demand: per.iter().map(|r| r.pages_demand).sum(),
            npu_busy_ms: per.iter().map(|r| r.npu_busy_ms).sum(),
            pim_busy_ms: per.iter().map(|r| r.pim_busy_ms).sum(),
            overlap_ms: per.iter().map(|r| r.overlap_ms).sum(),
            interleaved_steps: per
                .iter()
                .map(|r| r.interleaved_steps)
                .sum(),
            fused_steps: per.iter().map(|r| r.fused_steps).sum(),
            serial_saved_ms: per
                .iter()
                .map(|r| r.serial_saved_ms)
                .sum(),
            overlap_factor: {
                let npu: f64 = per.iter().map(|r| r.npu_busy_ms).sum();
                let pim: f64 = per.iter().map(|r| r.pim_busy_ms).sum();
                let over: f64 = per.iter().map(|r| r.overlap_ms).sum();
                let floor = npu.min(pim);
                if floor > 0.0 {
                    over / floor
                } else {
                    0.0
                }
            },
            per_class,
            queue_delay_ms: Percentiles::merge(&queue_parts),
            ttft_ms: Percentiles::merge(&ttft_parts),
            tpot_ms: Percentiles::merge(&tpot_parts),
        };
        let mean_busy = if busy_ms.is_empty() {
            0.0
        } else {
            busy_ms.iter().sum::<f64>() / busy_ms.len() as f64
        };
        let util_skew = if mean_busy > 0.0 {
            busy_ms.iter().fold(0.0, |a: f64, &b| a.max(b)) / mean_busy
        } else {
            1.0
        };
        ClusterReport {
            policy: policy.to_string(),
            replicas: n,
            fleet,
            per_replica: per
                .iter()
                .zip(busy_ms)
                .map(|(r, &b)| ReplicaLoad { report: r.clone(), busy_ms: b })
                .collect(),
            util_skew,
            scaling_efficiency: None,
        }
    }

    /// Attach the 1-replica baseline goodput (tok/s, same scenario and
    /// policy): scaling efficiency is fleet goodput over `replicas x`
    /// that baseline -- 1.0 is perfectly linear scaling.
    pub fn with_baseline(mut self, baseline_goodput_tok_s: f64) -> Self {
        if baseline_goodput_tok_s > 0.0 {
            self.scaling_efficiency = Some(
                self.fleet.goodput_tok_s
                    / (self.replicas as f64 * baseline_goodput_tok_s),
            );
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::traffic::{ReqRecord, SloSpec};

    fn rec(arrival: f64, first: f64, fin: f64, tokens: usize) -> ReqRecord {
        ReqRecord {
            arrival_ms: arrival,
            submitted_ms: arrival,
            prefill_start_ms: Some(arrival + 1.0),
            first_token_ms: Some(first),
            finished_ms: Some(fin),
            prompt_len: 16,
            tokens_generated: tokens,
            cached_prefix_tokens: 0,
            class: SloClass::Interactive,
            preemptions: 0,
            pages_swapped: 0,
            pages_recomputed: 0,
            pages_prefetched: 0,
            pages_demand: 0,
        }
    }

    fn report(records: &[ReqRecord]) -> LoadReport {
        LoadReport::from_records(
            records,
            &SloSpec::relaxed(),
            &Metrics::default(),
            None,
        )
    }

    #[test]
    fn merge_sums_counts_and_rebases_rates() {
        // replica 0: 2 requests over 1 s; replica 1: 1 request over 2 s
        let a = report(&[rec(0.0, 10.0, 500.0, 50), rec(0.0, 20.0, 1000.0, 50)]);
        let b = report(&[rec(0.0, 10.0, 2000.0, 80)]);
        let m = ClusterReport::merge(
            "jsq",
            &[a.clone(), b.clone()],
            &[800.0, 1200.0],
            None,
        );
        assert_eq!(m.replicas, 2);
        assert_eq!(m.fleet.offered, 3);
        assert_eq!(m.fleet.completed, 3);
        assert_eq!(m.fleet.slo_met, 3);
        assert!((m.fleet.slo_attainment - 1.0).abs() < 1e-12);
        assert!((m.fleet.makespan_ms - 2000.0).abs() < 1e-9);
        // token-exact: (100 + 80) tokens over the 2 s fleet span
        assert!((m.fleet.throughput_tok_s - 180.0 / 2.0).abs() < 1e-6);
        assert_eq!(m.fleet.ttft_ms.count, 3);
        // skew: max 1200 / mean 1000
        assert!((m.util_skew - 1.2).abs() < 1e-9);
        assert!(m.scaling_efficiency.is_none());
        let with = m.with_baseline(45.0);
        // 90 tok/s fleet goodput vs 2 x 45 baseline = 1.0
        assert!((with.scaling_efficiency.unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_carries_tier_rows_and_preemption_counters() {
        let mut int = rec(0.0, 10.0, 500.0, 50);
        int.class = SloClass::Interactive;
        let mut be = rec(0.0, 20.0, 1000.0, 50);
        be.class = SloClass::BestEffort;
        be.preemptions = 1;
        be.pages_recomputed = 7;
        let a = report(&[int, be]); // mixed tiers -> per_class set
        let b = report(&[rec(0.0, 10.0, 800.0, 30)]); // all-interactive
        let m = ClusterReport::merge("jsq", &[a, b], &[10.0, 10.0], None);
        assert_eq!(m.fleet.preemptions, 1);
        assert_eq!(m.fleet.pages_recomputed, 7);
        assert_eq!(m.fleet.pages_swapped, 0);
        // tier rows merge across replicas (replica b contributed no
        // rows of its own: single-class runs keep per_class empty)
        assert_eq!(m.fleet.per_class.len(), 2);
        let (c0, fi) = &m.fleet.per_class[0];
        assert_eq!(*c0, SloClass::Interactive);
        assert_eq!(fi.offered, 1);
        let (c1, fb) = &m.fleet.per_class[1];
        assert_eq!(*c1, SloClass::BestEffort);
        assert_eq!(fb.offered, 1);
        assert_eq!(fb.preemptions, 1);
        assert!(fb.per_class.is_empty());
    }

    #[test]
    fn merge_of_idle_replicas_is_well_defined() {
        let empty = report(&[]);
        let m = ClusterReport::merge(
            "rr",
            &[empty.clone(), empty],
            &[0.0, 0.0],
            None,
        );
        assert_eq!(m.fleet.offered, 0);
        assert_eq!(m.fleet.slo_attainment, 0.0);
        assert_eq!(m.fleet.throughput_tok_s, 0.0);
        assert_eq!(m.util_skew, 1.0);
        let none = ClusterReport::merge("rr", &[], &[], None);
        assert_eq!(none.fleet.offered, 0);
        assert!(none.fleet.saturation_tok_s.is_none());
    }

    #[test]
    fn explicit_fleet_span_prevents_offset_window_inflation() {
        // two replicas each busy for ~100 ms, but 10 s apart on the
        // global timeline: rebasing on max(per-replica window) would
        // claim ~1000 tok/s; the true fleet span says ~10 tok/s
        let a = report(&[rec(0.0, 10.0, 100.0, 50)]);
        let b = report(&[rec(10_000.0, 10_010.0, 10_100.0, 50)]);
        let m = ClusterReport::merge(
            "rr",
            &[a, b],
            &[90.0, 90.0],
            Some(10_100.0),
        );
        assert!((m.fleet.makespan_ms - 10_100.0).abs() < 1e-9);
        let want = 100.0 * 1e3 / 10_100.0; // 100 tokens over 10.1 s
        assert!(
            (m.fleet.throughput_tok_s - want).abs() < 1e-6,
            "{} vs {want}",
            m.fleet.throughput_tok_s
        );
    }
}
