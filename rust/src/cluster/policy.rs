//! Pluggable request-routing policies for a multi-replica fleet.
//!
//! A policy sees a cheap [`RouteQuery`] describing the request (length
//! shape plus its prefix-affinity hash) and a snapshot of every
//! candidate replica (queue depth, live decode lanes, KV pool
//! occupancy, local clock), and picks where the next request lands.
//! Colocated policies route every request to one replica that does
//! both prefill and decode; the disaggregated policy splits the fleet
//! into a prefill pool and a decode pool (NeuPIMs/DistServe-style),
//! with the finished KV handed over at a modeled transfer cost (see
//! [`Cluster`](super::fleet::Cluster)).
//!
//! All policies are deterministic: ties break on the lowest replica
//! index, so a fixed seed replays the identical placement sequence.

/// What a policy may observe about the request being placed.
#[derive(Debug, Clone, Copy)]
pub struct RouteQuery {
    pub prompt_len: usize,
    pub max_new: usize,
    /// content hash of the prompt's first KV page
    /// ([`prefix_page_hash`](crate::coordinator::prefix_page_hash));
    /// `None` when the prompt is shorter than one page.  Requests
    /// sharing a system prompt share this value -- the signal the
    /// `pa` policy routes on to keep prefix caches replica-local.
    pub affinity: Option<u64>,
    /// SLO priority tier the request was submitted under.  No shipped
    /// policy reads it yet; it is part of the query contract so
    /// tier-aware placement (e.g. reserving replicas for interactive
    /// traffic) needs no signature change.
    pub class: crate::sched::SloClass,
}

/// What a policy may observe about one replica at routing time.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSnapshot {
    /// fleet index of this replica
    pub index: usize,
    /// requests waiting for admission
    pub queued: usize,
    /// requests holding a decode lane
    pub active: usize,
    /// packed bytes live in the KV pool
    pub kv_used_bytes: usize,
    /// replica-local clock (ms).  No shipped policy reads it yet; it
    /// is part of the snapshot contract for clock/staleness-aware
    /// policies (route away from replicas that have run far ahead).
    pub now_ms: f64,
}

impl ReplicaSnapshot {
    /// Outstanding requests on this replica (the JSQ metric).
    pub fn depth(&self) -> usize {
        self.queued + self.active
    }
}

/// Where a fresh arrival (and, for disaggregated fleets, a decode
/// continuation) should run.  `route*` receives non-empty candidate
/// snapshots and returns the chosen replica's fleet `index`.
pub trait RoutePolicy {
    /// Registry name (`--policy`).
    fn name(&self) -> &'static str;

    /// Replicas that take fresh arrivals.  Identity for colocated
    /// policies; the prefill pool for disaggregated ones.
    fn prefill_pool(&self, replicas: usize) -> Vec<usize> {
        (0..replicas).collect()
    }

    /// `Some(pool)` when finished prefills hand their KV to a separate
    /// decode pool; `None` for colocated serving.
    fn decode_pool(&self, replicas: usize) -> Option<Vec<usize>> {
        let _ = replicas;
        None
    }

    /// Pick a replica for a fresh arrival.
    fn route(
        &mut self,
        query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize;

    /// Pick a replica for a decode continuation (disaggregated
    /// fleets); defaults to the fresh-arrival rule.
    fn route_decode(
        &mut self,
        query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize {
        self.route(query, candidates)
    }
}

/// Index of the candidate minimizing `key` (first wins ties: snapshots
/// are passed in ascending fleet order, so ties break low).
fn argmin_by<K: PartialOrd>(
    candidates: &[ReplicaSnapshot],
    key: impl Fn(&ReplicaSnapshot) -> K,
) -> usize {
    let mut best = 0usize;
    for (i, c) in candidates.iter().enumerate().skip(1) {
        if key(c) < key(&candidates[best]) {
            best = i;
        }
    }
    candidates[best].index
}

/// Static rotation, blind to load: the baseline every adaptive policy
/// is measured against.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(
        &mut self,
        _query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize {
        let pick = candidates[self.next % candidates.len()].index;
        self.next = self.next.wrapping_add(1);
        pick
    }
}

/// Join-shortest-queue: route to the replica with the fewest
/// outstanding requests (queued + active lanes).
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl RoutePolicy for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn route(
        &mut self,
        _query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize {
        argmin_by(candidates, |c| c.depth())
    }
}

/// Least-KV-loaded: route to the replica holding the fewest live KV
/// bytes (queue depth breaks ties).  Long-context mixes skew KV much
/// harder than request counts, which is what this policy balances.
#[derive(Debug, Default)]
pub struct LeastKvLoaded;

impl RoutePolicy for LeastKvLoaded {
    fn name(&self) -> &'static str {
        "kv"
    }

    fn route(
        &mut self,
        _query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize {
        argmin_by(candidates, |c| (c.kv_used_bytes, c.depth()))
    }
}

/// Prefix-affinity: requests sharing a first-page prefix hash land on
/// the same replica (`hash % candidates`), so each replica's
/// shared-prefix KV cache serves its own tenant slice instead of every
/// replica cold-missing every system prompt.  Prefix-less prompts
/// (shorter than one KV page) fall back to join-shortest-queue.
///
/// Deterministic and stateless; the trade is load balance for cache
/// locality, which pays off exactly when the workload carries popular
/// shared prefixes (`agent`, `rag-cached` mixes).
#[derive(Debug, Default)]
pub struct PrefixAffinity;

impl RoutePolicy for PrefixAffinity {
    fn name(&self) -> &'static str {
        "pa"
    }

    fn route(
        &mut self,
        query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize {
        match query.affinity {
            Some(h) => candidates[(h % candidates.len() as u64) as usize].index,
            None => argmin_by(candidates, |c| c.depth()),
        }
    }
}

/// Prefill/decode disaggregation: the first `ceil(n/4)` (min 1)
/// replicas form the prefill pool, the rest the decode pool.  Fresh
/// arrivals JSQ over the prefill pool; finished prefills hand their KV
/// to the least-KV-loaded decode replica.  A 1-replica fleet has no
/// second pool and degrades to colocated serving (no handoff).
#[derive(Debug, Default)]
pub struct PrefillDecode;

impl PrefillDecode {
    /// Prefill-side replica count for an `n`-replica fleet:
    /// `ceil(n/4)`, always leaving at least one decode replica when
    /// the fleet has two or more.
    pub fn prefill_share(n: usize) -> usize {
        if n <= 1 {
            return 1;
        }
        n.div_ceil(4).min(n - 1)
    }
}

impl RoutePolicy for PrefillDecode {
    fn name(&self) -> &'static str {
        "pd"
    }

    fn prefill_pool(&self, replicas: usize) -> Vec<usize> {
        (0..Self::prefill_share(replicas)).collect()
    }

    fn decode_pool(&self, replicas: usize) -> Option<Vec<usize>> {
        if replicas < 2 {
            return None;
        }
        Some((Self::prefill_share(replicas)..replicas).collect())
    }

    fn route(
        &mut self,
        _query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize {
        argmin_by(candidates, |c| c.depth())
    }

    fn route_decode(
        &mut self,
        _query: &RouteQuery,
        candidates: &[ReplicaSnapshot],
    ) -> usize {
        argmin_by(candidates, |c| (c.kv_used_bytes, c.depth()))
    }
}

/// Registry names (`cluster --policy all` / `--list`).
pub fn all_policy_names() -> Vec<&'static str> {
    vec!["rr", "jsq", "kv", "pa", "pd"]
}

/// One-line description per policy (CLI `--list`).
pub fn policy_desc(name: &str) -> &'static str {
    match name {
        "rr" => "round-robin rotation, blind to load",
        "jsq" => "join-shortest-queue (queued + active lanes)",
        "kv" => "least-KV-loaded (live pool bytes, depth tiebreak)",
        "pa" => "prefix-affinity (route by shared-prefix hash; JSQ fallback)",
        "pd" => "prefill/decode disaggregation with modeled KV handoff",
        _ => "",
    }
}

/// Case-insensitive policy lookup (accepts short and long spellings).
pub fn policy_by_name(name: &str) -> Option<Box<dyn RoutePolicy>> {
    match name.to_ascii_lowercase().as_str() {
        "rr" | "round-robin" | "roundrobin" => {
            Some(Box::new(RoundRobin::default()))
        }
        "jsq" | "join-shortest-queue" => {
            Some(Box::new(JoinShortestQueue))
        }
        "kv" | "least-kv" | "least-kv-loaded" => {
            Some(Box::new(LeastKvLoaded))
        }
        "pa" | "prefix-affinity" | "affinity" => {
            Some(Box::new(PrefixAffinity))
        }
        "pd" | "prefill-decode" | "disagg" => {
            Some(Box::new(PrefillDecode))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(index: usize, queued: usize, active: usize, kv: usize) -> ReplicaSnapshot {
        ReplicaSnapshot { index, queued, active, kv_used_bytes: kv, now_ms: 0.0 }
    }

    fn q(prompt_len: usize, max_new: usize) -> RouteQuery {
        RouteQuery {
            prompt_len,
            max_new,
            affinity: None,
            class: crate::sched::SloClass::Interactive,
        }
    }

    #[test]
    fn registry_resolves_every_advertised_name() {
        for n in all_policy_names() {
            let p = policy_by_name(n).unwrap();
            assert_eq!(p.name(), n);
            assert!(!policy_desc(n).is_empty());
        }
        assert!(policy_by_name("JSQ").is_some());
        assert!(policy_by_name("prefix-affinity").is_some());
        assert!(policy_by_name("magic").is_none());
    }

    #[test]
    fn round_robin_rotates() {
        let mut p = RoundRobin::default();
        let c = [snap(0, 9, 9, 9), snap(1, 0, 0, 0), snap(2, 5, 5, 5)];
        let picks: Vec<usize> =
            (0..6).map(|_| p.route(&q(8, 8), &c)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_the_shallowest_and_ties_break_low() {
        let mut p = JoinShortestQueue;
        let c = [snap(0, 2, 1, 0), snap(1, 0, 1, 0), snap(2, 1, 0, 0)];
        assert_eq!(p.route(&q(8, 8), &c), 1);
        let tied = [snap(0, 1, 1, 0), snap(1, 0, 2, 0), snap(2, 2, 0, 0)];
        assert_eq!(p.route(&q(8, 8), &tied), 0);
    }

    #[test]
    fn least_kv_prefers_empty_pools() {
        let mut p = LeastKvLoaded;
        let c = [snap(0, 0, 0, 4096), snap(1, 3, 3, 128), snap(2, 0, 0, 128)];
        // 1 and 2 tie on bytes; depth breaks toward 2
        assert_eq!(p.route(&q(8, 8), &c), 2);
    }

    #[test]
    fn prefix_affinity_is_sticky_and_falls_back_to_jsq() {
        let mut p = PrefixAffinity;
        let c = [snap(0, 5, 5, 0), snap(1, 0, 0, 0), snap(2, 1, 1, 0)];
        let with = |h: u64| RouteQuery {
            prompt_len: 64,
            max_new: 8,
            affinity: Some(h),
            class: crate::sched::SloClass::Interactive,
        };
        // same affinity hash -> same replica, regardless of load
        let a = p.route(&with(0xABCD), &c);
        for _ in 0..4 {
            assert_eq!(p.route(&with(0xABCD), &c), a);
        }
        // hashes spread across the fleet
        let spread: std::collections::HashSet<usize> =
            (0..32u64).map(|h| p.route(&with(h), &c)).collect();
        assert_eq!(spread.len(), 3);
        // the placement is hash % candidates on fleet indices
        assert_eq!(p.route(&with(4), &c), (4 % 3) as usize);
        // prefix-less prompts JSQ to the shallowest replica
        assert_eq!(p.route(&q(8, 8), &c), 1);
    }

    #[test]
    fn pd_pools_partition_the_fleet() {
        let p = PrefillDecode;
        assert_eq!(p.prefill_pool(1), vec![0]);
        assert!(p.decode_pool(1).is_none());
        for n in [2usize, 3, 4, 8, 9] {
            let pre = p.prefill_pool(n);
            let dec = p.decode_pool(n).unwrap();
            assert!(!pre.is_empty() && !dec.is_empty(), "n={n}");
            // disjoint and covering
            let mut all: Vec<usize> =
                pre.iter().chain(dec.iter()).copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n}");
        }
        // ceil(n/4), as documented
        assert_eq!(PrefillDecode::prefill_share(4), 1);
        assert_eq!(PrefillDecode::prefill_share(5), 2);
        assert_eq!(PrefillDecode::prefill_share(8), 2);
        assert_eq!(PrefillDecode::prefill_share(9), 3);
    }
}
