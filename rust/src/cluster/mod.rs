//! L3.75 cluster: multi-replica NPU-PIM serving behind a pluggable
//! router.
//!
//! The paper's speedups are per accelerator; the production question
//! is what a *fleet* of them sustains.  This layer scales the serving
//! stack out: a [`Cluster`] owns N sim-backend
//! [`Engine`](crate::coordinator::Engine) replicas on one lock-stepped
//! virtual clock, a [`RoutePolicy`] decides where each arrival lands,
//! and a [`ClusterReport`] merges the per-replica
//! [`LoadReport`](crate::traffic::LoadReport)s into fleet goodput, SLO
//! attainment, utilization skew, and scaling efficiency against a
//! 1-replica baseline.
//!
//! Policies (see `p3llm cluster --list`):
//!
//! * `rr`  -- round-robin rotation (the load-blind baseline)
//! * `jsq` -- join-shortest-queue over queued + active lanes
//! * `kv`  -- least-KV-loaded (live pool bytes)
//! * `pd`  -- prefill/decode disaggregation: prompts run on a prefill
//!   pool, the finished KV migrates to a decode pool at a transfer
//!   cost priced from the `sim::dram` event model / HBM external bus
//!   (NeuPIMs' sub-batch split and IANUS' unified-memory scheduling
//!   are the motivating designs)
//!
//! ```ignore
//! let sc = traffic::scenario_by_name("chat-poisson").unwrap();
//! let mut fleet = Cluster::from_scenario(&sc, "P3-LLM", None, 4, "jsq")?;
//! let plan = sc.clone().for_fleet(4)?.runner(7);
//! let out = fleet.run(&plan, sc.saturation_tok_s("P3-LLM"))?;
//! println!("fleet goodput {:.1} tok/s, skew {:.2}",
//!          out.report.fleet.goodput_tok_s, out.report.util_skew);
//! ```
//!
//! Whole cluster runs are bit-identical under a fixed seed: routing is
//! deterministic (ties break on replica index) and every replica clock
//! derives from the same cost model.

pub mod fleet;
pub mod policy;
pub mod report;

pub use fleet::{Cluster, ClusterOutcome};
pub use policy::{
    all_policy_names, policy_by_name, policy_desc, JoinShortestQueue,
    LeastKvLoaded, PrefillDecode, ReplicaSnapshot, RoundRobin, RoutePolicy,
};
pub use report::{ClusterReport, ReplicaLoad};
