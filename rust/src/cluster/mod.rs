//! L3.75 cluster: multi-replica NPU-PIM serving behind a pluggable
//! router.
//!
//! The paper's speedups are per accelerator; the production question
//! is what a *fleet* of them sustains.  This layer scales the serving
//! stack out: a [`Cluster`] owns N sim-backend
//! [`Engine`](crate::coordinator::Engine) replicas on one lock-stepped
//! virtual clock, a [`RoutePolicy`] decides where each arrival lands,
//! and a [`ClusterReport`] merges the per-replica
//! [`LoadReport`](crate::traffic::LoadReport)s into fleet goodput, SLO
//! attainment, utilization skew, and scaling efficiency against a
//! 1-replica baseline.
//!
//! Policies (see `p3llm cluster --list`):
//!
//! * `rr`  -- round-robin rotation (the load-blind baseline)
//! * `jsq` -- join-shortest-queue over queued + active lanes
//! * `kv`  -- least-KV-loaded (live pool bytes)
//! * `pa`  -- prefix-affinity: route by the prompt's first-page
//!   content hash ([`prefix_page_hash`](crate::coordinator::prefix_page_hash)),
//!   so requests sharing a system prompt land on the same replica and
//!   its shared-prefix KV cache stays hot (replica-local caches
//!   instead of every replica cold-missing every tenant)
//! * `pd`  -- prefill/decode disaggregation: prompts run on a prefill
//!   pool, the finished KV migrates to a decode pool at a transfer
//!   cost priced from the `sim::dram` event model / HBM external bus
//!   (NeuPIMs' sub-batch split and IANUS' unified-memory scheduling
//!   are the motivating designs)
//!
//! ```
//! use p3llm::cluster::Cluster;
//! use p3llm::traffic;
//! # fn main() -> p3llm::Result<()> {
//! let sc = traffic::scenario_by_name("smoke").unwrap();
//! let mut fleet = Cluster::from_scenario(&sc, "P3-LLM", None, 2, "jsq")?;
//! let plan = sc.clone().for_fleet(2)?.runner(7);
//! let out = fleet.run(&plan, sc.saturation_tok_s("P3-LLM"))?;
//! assert!(out.report.fleet.goodput_tok_s > 0.0);
//! println!("fleet goodput {:.1} tok/s, skew {:.2}",
//!          out.report.fleet.goodput_tok_s, out.report.util_skew);
//! # Ok(())
//! # }
//! ```
//!
//! Whole cluster runs are bit-identical under a fixed seed: routing is
//! deterministic (ties break on replica index) and every replica clock
//! derives from the same cost model.
//!
//! Requests carry their [`SloClass`](crate::sched::SloClass) through
//! the [`RouteQuery`] (tier-aware policies need no signature change)
//! and into each replica, so a fleet of preemptively-scheduled engines
//! (scenarios with a victim policy, e.g. `flash-crowd`) reports
//! per-tier fleet rows and preemption counters in its merged
//! [`ClusterReport`].

pub mod fleet;
pub mod policy;
pub mod report;

pub use fleet::{Cluster, ClusterOutcome};
pub use policy::{
    all_policy_names, policy_by_name, policy_desc, JoinShortestQueue,
    LeastKvLoaded, PrefillDecode, PrefixAffinity, ReplicaSnapshot,
    RoundRobin, RoutePolicy, RouteQuery,
};
pub use report::{ClusterReport, ReplicaLoad};
