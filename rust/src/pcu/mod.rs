//! Functional (bit-accurate) model of the P3-LLM PCU datapath
//! (paper Fig. 6a right): 16 PEs, each computing a 4-way dot product of
//! 8-bit inputs (FP8 mantissa+exponent) against decoded 4-bit weights
//! through a 6-bit fixed-point multiplier, exponent shift, 4:2
//! compressor tree and 32-bit fixed-point accumulation.
//!
//! Used to validate that the integer datapath reproduces the fake-quant
//! arithmetic the AOT graphs use (within the fixed-point accumulator's
//! quantization), and to ground the Table VIII MAC counting.

use crate::quant::bitmod::{tables, BitmodGroup};
use crate::quant::int::Int4Group;

/// Fixed-point scale of the 32-bit accumulator (fractional bits).
/// The product of a 5-bit mantissa and a 6-bit decoded operand is
/// shifted by the input exponent; we keep 16 fractional bits.
const FRAC_BITS: i32 = 16;

/// An FP8-ish input as the PCU sees it: sign+mantissa (6-bit signed
/// fixed point, 1.4 format => value = m * 2^e with |m| < 2).
#[derive(Debug, Clone, Copy)]
pub struct PcuInput {
    pub mantissa: i8, // signed, 5 significant bits (1 hidden + 4)
    pub exponent: i8,
}

/// Decompose an f32 on the FP8-E4M3 / S0E4M4 grid into PCU form.
pub fn decompose_fp8(x: f32) -> PcuInput {
    if x == 0.0 {
        return PcuInput { mantissa: 0, exponent: 0 };
    }
    let e = x.abs().log2().floor() as i32;
    // mantissa in [1, 2) scaled to 4 fractional bits -> 5-bit magnitude
    let m = (x.abs() / (e as f32).exp2() * 16.0).round() as i32;
    let m = m.min(31);
    PcuInput {
        mantissa: if x < 0.0 { -(m as i8) } else { m as i8 },
        exponent: e as i8,
    }
}

/// One PE: dot product of 4 inputs against 4 decoded weights with
/// integer arithmetic only (products shifted by input exponents into a
/// shared fixed-point frame, 4:2-compressed, accumulated at 32 bits).
pub fn pe_dot4_int4(
    inputs: &[PcuInput; 4],
    weights: &Int4Group,
    idx: usize,
    acc: &mut i64,
) {
    // INT4-Asym decode: w = code * scale + zero. The PCU multiplies the
    // *code* (plus zero-point handling) and defers scale to the epilogue;
    // here we model the datapath: mul in integer, shift by exponent.
    for (j, inp) in inputs.iter().enumerate() {
        let code = weights.codes[idx + j] as i32; // 0..15 (5-bit w/ zp)
        let prod = inp.mantissa as i32 * code; // 6-bit x 5-bit
        let sh = inp.exponent as i32 + FRAC_BITS - 4; // mantissa has 4 frac bits
        let shifted = if sh >= 0 {
            (prod as i64) << sh
        } else {
            (prod as i64) >> (-sh)
        };
        *acc += shifted;
    }
}

/// Full PCU GEMV tile (1x4x16) against INT4-Asym weights, returning the
/// dequantized f32 results: code-domain accumulation + scale/zero
/// epilogue (the NPU-side dequant fusion of Fig. 6c).
pub fn pcu_tile_int4(
    inputs: &[PcuInput; 4],
    weight_groups: &[Int4Group; 16],
    input_vals: &[f32; 4],
) -> [f32; 16] {
    let mut out = [0.0f32; 16];
    let in_sum: f32 = input_vals.iter().sum();
    for (pe, wg) in weight_groups.iter().enumerate() {
        let mut acc = 0i64;
        pe_dot4_int4(inputs, wg, 0, &mut acc);
        let code_dot = acc as f32 / (1u64 << FRAC_BITS) as f32;
        // x . (c*s + z) = s * (x . c) + z * sum(x)
        out[pe] = wg.scale * code_dot + wg.zero * in_sum;
    }
    out
}

/// BitMoD weight decode through the PCU's 6-bit fixed-point domain:
/// table values {0,..,±6,special} scale by 2 to become integers
/// (±1,±2,...,±12,±16) -- exactly the 6-bit signed range the paper's
/// multiplier width argument relies on.
pub fn bitmod_code_to_fixed(g: &BitmodGroup, idx: usize) -> i32 {
    let tab = tables()[g.special as usize];
    (tab[g.codes[idx] as usize] * 2.0) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fp8::fp8_e4m3;
    use crate::quant::int::quant_group_int4;

    #[test]
    fn decompose_roundtrip() {
        for v in [1.0f32, -0.75, 448.0, 0.015625, 3.5] {
            let q = fp8_e4m3(v);
            let d = decompose_fp8(q);
            let back = d.mantissa as f32 / 16.0 * (d.exponent as f32).exp2();
            assert!((back - q).abs() <= q.abs() * 0.001, "{q} vs {back}");
        }
    }

    #[test]
    fn pcu_tile_matches_float_reference() {
        let xs = [0.5f32, -1.25, 2.0, 0.375];
        let xq: Vec<f32> = xs.iter().map(|&v| fp8_e4m3(v)).collect();
        let inputs: [PcuInput; 4] =
            std::array::from_fn(|i| decompose_fp8(xq[i]));
        let mut rng = crate::testutil::Rng::new(5);
        let groups: [Int4Group; 16] = std::array::from_fn(|_| {
            let w = rng.vec_f32(4, -1.0, 1.0);
            quant_group_int4(&w)
        });
        let got = pcu_tile_int4(
            &inputs,
            &groups,
            &[xq[0], xq[1], xq[2], xq[3]],
        );
        for (pe, wg) in groups.iter().enumerate() {
            let mut w = vec![0.0f32; 4];
            crate::quant::int::dequant_group_int4(wg, &mut w);
            let want: f32 = w.iter().zip(&xq).map(|(a, b)| a * b).sum();
            assert!(
                (got[pe] - want).abs() <= want.abs() * 1e-3 + 1e-4,
                "pe{pe}: {} vs {want}",
                got[pe]
            );
        }
    }

    #[test]
    fn bitmod_fixed_domain_fits_6_bits() {
        let w: Vec<f32> = (0..128).map(|i| ((i * 13) % 17) as f32 / 10.0 - 0.8).collect();
        let g = crate::quant::bitmod::bitmod_encode_group(&w);
        for i in 0..128 {
            let f = bitmod_code_to_fixed(&g, i);
            assert!((-32..=31).contains(&f), "{f}");
        }
    }
}
