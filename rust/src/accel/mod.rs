//! Accelerator system models (paper Section VI baselines + P3-LLM).
//!
//! Every system is an instance of [`Accel`]: a quantization scheme, an
//! optional PIM subsystem, and the operator-mapping policy of Fig. 6(b)
//! -- the same cost-based mapper the L3 coordinator uses online.  The
//! policy picks, per operator, the cheaper of NPU and PIM execution
//! (when the operator is PIM-eligible under the scheme), which
//! reproduces the paper's behaviours: HBM-PIM losing to the NPU at
//! batch >= 4, P3 offloading linears back to the NPU at batch >= 8
//! (Fig. 16), and pre-RoPE models keeping Q.K^T on the NPU (Fig. 11).

use crate::config::accel::{PcuConfig, PimConfig, SystemConfig};
use crate::config::llm::{LlmConfig, RopeStage};
use crate::config::scheme::QuantScheme;
use crate::sim::{npu, pim::PimGemm, Cost};
use crate::workload::{decode_trace, prefill_trace, Op, OpClass, Operand};

/// Per-class cost of one decode step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepCost {
    pub attn: Cost,
    pub linear: Cost,
    pub other: Cost,
}

impl StepCost {
    pub fn total_ns(&self) -> f64 {
        self.attn.ns + self.linear.ns + self.other.ns
    }
    pub fn total_pj(&self) -> f64 {
        self.attn.pj + self.linear.pj + self.other.pj
    }
    fn slot(&mut self, class: OpClass) -> &mut Cost {
        match class {
            OpClass::Attention => &mut self.attn,
            OpClass::Linear => &mut self.linear,
            OpClass::Other => &mut self.other,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Accel {
    pub name: &'static str,
    pub scheme: QuantScheme,
    pub system: SystemConfig,
}

impl Accel {
    pub fn npu_fp16() -> Self {
        Accel {
            name: "NPU",
            scheme: QuantScheme::fp16(),
            system: SystemConfig::npu_only(),
        }
    }

    pub fn hbm_pim() -> Self {
        Accel {
            name: "HBM-PIM",
            scheme: QuantScheme::fp16(),
            system: SystemConfig::with_pcu(PcuConfig::hbm_pim()),
        }
    }

    pub fn ecco() -> Self {
        Accel {
            name: "Ecco",
            scheme: QuantScheme::ecco(),
            system: SystemConfig::npu_only(),
        }
    }

    pub fn p3llm() -> Self {
        Accel {
            name: "P3-LLM",
            scheme: QuantScheme::p3llm(),
            system: SystemConfig::with_pcu(PcuConfig::p3llm()),
        }
    }

    pub fn p3llm_no_tep() -> Self {
        Accel {
            name: "P3-noTEP",
            scheme: QuantScheme::p3llm(),
            system: SystemConfig::with_pcu(PcuConfig::p3llm_no_tep()),
        }
    }

    /// Fig. 15 step 2: W4A8KV4 quantization on PIM, fp16 scores, no TEP.
    pub fn pim_w4a8kv4() -> Self {
        Accel {
            name: "PIM-W4A8KV4",
            scheme: QuantScheme::p3_no_p8(),
            system: SystemConfig::with_pcu(PcuConfig::p3llm_no_tep()),
        }
    }

    /// Fig. 15 step 3: + throughput-enhanced PCU, still fp16 scores.
    pub fn pim_w4a8kv4_tep() -> Self {
        Accel {
            name: "PIM-W4A8KV4+TEP",
            scheme: QuantScheme::p3_no_p8(),
            system: SystemConfig::with_pcu(PcuConfig::p3llm()),
        }
    }

    pub fn pimba_orig() -> Self {
        Accel {
            name: "Pimba",
            scheme: QuantScheme::pimba_orig(),
            system: SystemConfig::with_pcu(PcuConfig::pimba()),
        }
    }

    pub fn pimba_enhanced() -> Self {
        Accel {
            name: "Pimba-W8A8",
            scheme: QuantScheme::pimba_enhanced(),
            system: SystemConfig::with_pcu(PcuConfig::pimba()),
        }
    }

    pub fn smoothquant() -> Self {
        Accel {
            name: "SmoothQuant",
            scheme: QuantScheme::smoothquant(),
            system: SystemConfig::npu_only(),
        }
    }

    pub fn awq() -> Self {
        Accel {
            name: "AWQ",
            scheme: QuantScheme::awq(),
            system: SystemConfig::npu_only(),
        }
    }

    fn stored_bits(&self, operand: Operand) -> f64 {
        match operand {
            Operand::Weight => self.scheme.bits.weights,
            Operand::KeyCache | Operand::ValueCache => self.scheme.bits.kv,
        }
    }

    /// Is this GEMM eligible for the PIM under the scheme + RoPE stage?
    fn pim_eligible(&self, model: &LlmConfig, name: &str, operand: Operand) -> bool {
        let Some(_) = self.system.pim else { return false };
        match operand {
            Operand::Weight => true,
            Operand::KeyCache => {
                // pre-RoPE quantized keys lack positional info: Q.K^T
                // must run on the NPU after online RoPE (Section V-B)
                !(name == "qk" && model.rope_stage == RopeStage::Pre
                    && self.scheme.bits.kv < 16.0)
            }
            Operand::ValueCache => {
                // P.V on PIM needs quantized scores (Section IV-B)
                self.scheme.attention_on_pim
            }
        }
    }

    fn npu_cost(&self, g: &Op) -> Cost {
        let Op::Gemm { m, k, n, count, operand, .. } = g else {
            unreachable!()
        };
        let act_bits = match operand {
            Operand::Weight => self.scheme.bits.activations,
            Operand::KeyCache => self.scheme.bits.activations, // query
            Operand::ValueCache => self.scheme.bits.scores,
        };
        npu::gemm(
            &self.system.npu,
            &self.system.hbm,
            npu::NpuGemm {
                m: *m,
                k: *k,
                n: *n,
                count: *count,
                stored_bits: self.stored_bits(*operand),
                act_bits,
                decompress_factor: if self.scheme.npu_decompress { 1.15 } else { 1.0 },
            },
        )
    }

    fn pim_cost(&self, pimc: &PimConfig, g: &Op) -> Cost {
        let Op::Gemm { m, k, n, count, operand, .. } = g else {
            unreachable!()
        };
        let mut c = pimc.gemm(PimGemm {
            m: *m,
            k: *k,
            n: *n,
            count: *count,
            stored_bits: self.stored_bits(*operand),
        });
        // results return to the NPU over the external bus (fp16 partials)
        let out_bytes = (*m * *n * *count) as f64 * 2.0;
        c.add(npu::transfer(&self.system.hbm, out_bytes));
        c
    }

    /// Cost-based operator mapping + timing for one decode step.
    pub fn decode_step(&self, model: &LlmConfig, bs: usize, ctx: usize) -> StepCost {
        let mut out = StepCost::default();
        for op in decode_trace(model, bs, ctx) {
            let class = op.class();
            let cost = match &op {
                Op::Vector { elems, .. } => npu::vector(&self.system.npu, *elems),
                Op::Gemm { name, operand, .. } => {
                    let npu_c = self.npu_cost(&op);
                    match (&self.system.pim, self.pim_eligible(model, name, *operand)) {
                        (Some(p), true) => {
                            let pim_c = self.pim_cost(p, &op);
                            if pim_c.ns <= npu_c.ns {
                                pim_c
                            } else {
                                npu_c
                            }
                        }
                        _ => npu_c,
                    }
                }
            };
            out.slot(class).add(cost);
        }
        out
    }

    /// Public cost accessors for the online mapper (`coordinator::mapper`).
    pub fn npu_cost_pub(&self, g: &Op) -> Cost {
        self.npu_cost(g)
    }

    pub fn pim_cost_pub(&self, p: &PimConfig, g: &Op) -> Cost {
        self.pim_cost(p, g)
    }

    pub fn pim_eligible_pub(
        &self,
        model: &LlmConfig,
        name: &str,
        operand: Operand,
    ) -> bool {
        self.pim_eligible(model, name, operand)
    }

    /// Decode throughput in tokens/s at the given batch.
    pub fn decode_tokens_per_sec(&self, model: &LlmConfig, bs: usize, ctx: usize) -> f64 {
        let ns = self.decode_step(model, bs, ctx).total_ns();
        bs as f64 / (ns * 1e-9)
    }

    /// Prefill latency (ms) of one request over `n_tokens` prompt
    /// tokens.  Prefill is always NPU territory -- compute-bound GEMM
    /// (Section II) -- regardless of the PIM configuration.
    pub fn prefill_ms(&self, model: &LlmConfig, n_tokens: usize) -> f64 {
        let mut ns = 0.0;
        for op in prefill_trace(model, 1, n_tokens) {
            ns += match &op {
                Op::Vector { elems, .. } => {
                    npu::vector(&self.system.npu, *elems).ns
                }
                Op::Gemm { .. } => self.npu_cost(&op).ns,
            };
        }
        ns / 1e6
    }
}

/// The Fig. 9 baseline set.
pub fn fig9_systems() -> Vec<Accel> {
    vec![Accel::npu_fp16(), Accel::hbm_pim(), Accel::ecco(), Accel::p3llm()]
}

/// Every named system (the `EngineBuilder --system` registry).
pub fn all_systems() -> Vec<Accel> {
    vec![
        Accel::npu_fp16(),
        Accel::hbm_pim(),
        Accel::ecco(),
        Accel::p3llm(),
        Accel::p3llm_no_tep(),
        Accel::pim_w4a8kv4(),
        Accel::pim_w4a8kv4_tep(),
        Accel::pimba_orig(),
        Accel::pimba_enhanced(),
        Accel::smoothquant(),
        Accel::awq(),
    ]
}

/// Case-insensitive lookup by system name (e.g. "P3-LLM", "hbm-pim").
pub fn by_name(name: &str) -> Option<Accel> {
    all_systems()
        .into_iter()
        .find(|a| a.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::llm::{LLAMA2_7B, LLAMA31_8B, MISTRAL_7B};

    #[test]
    fn system_registry_lookup() {
        assert_eq!(by_name("p3-llm").unwrap().name, "P3-LLM");
        assert_eq!(by_name("HBM-PIM").unwrap().name, "HBM-PIM");
        assert!(by_name("warp-drive").is_none());
        for a in all_systems() {
            assert_eq!(by_name(a.name).unwrap().name, a.name);
        }
    }

    #[test]
    fn fig9_ordering_at_low_batch() {
        for m in [&LLAMA2_7B, &LLAMA31_8B] {
            let npu = Accel::npu_fp16().decode_step(m, 1, 4096).total_ns();
            let hbm = Accel::hbm_pim().decode_step(m, 1, 4096).total_ns();
            let ecco = Accel::ecco().decode_step(m, 1, 4096).total_ns();
            let p3 = Accel::p3llm().decode_step(m, 1, 4096).total_ns();
            assert!(hbm < npu, "{}: HBM-PIM should beat NPU at bs=1", m.name);
            assert!(ecco < npu);
            assert!(p3 < ecco, "{}: P3 {p3} vs Ecco {ecco}", m.name);
            assert!(p3 < hbm);
        }
    }

    #[test]
    fn hbm_pim_advantage_fades_at_bs4_for_gqa() {
        // Fig. 9: "as the batch size reaches 4, the performance
        // advantage of HBM-PIM ... disappears for Llama-3 and Mistral"
        let m = &MISTRAL_7B;
        let npu = Accel::npu_fp16().decode_step(m, 4, 4096).total_ns();
        let hbm = Accel::hbm_pim().decode_step(m, 4, 4096).total_ns();
        assert!(hbm > 0.8 * npu, "hbm {hbm} npu {npu}");
    }

    #[test]
    fn p3_peak_speedup_at_bs2() {
        // Fig. 9: P3's highest speedup over HBM-PIM lands at batch 2
        // (TEP processes two inputs per weight read)
        let m = &LLAMA31_8B;
        let s = |bs| {
            Accel::hbm_pim().decode_step(m, bs, 4096).total_ns()
                / Accel::p3llm().decode_step(m, bs, 4096).total_ns()
        };
        let (s1, s2, s4) = (s(1), s(2), s(4));
        assert!(s2 > s1, "{s1} {s2}");
        assert!(s2 >= s4 * 0.95, "{s2} {s4}");
    }

    #[test]
    fn avg_speedups_in_paper_ballpark() {
        // paper: 7.8x over NPU, 4.9x over HBM-PIM, 2.0x over Ecco
        // (averaged over models and batch sizes 1..8)
        let models = crate::config::llm::eval_models();
        let mut r_npu = vec![];
        let mut r_hbm = vec![];
        let mut r_ecco = vec![];
        for m in &models {
            for bs in [1usize, 2, 4, 8] {
                let p3 = Accel::p3llm().decode_step(m, bs, 4096).total_ns();
                r_npu.push(Accel::npu_fp16().decode_step(m, bs, 4096).total_ns() / p3);
                r_hbm.push(Accel::hbm_pim().decode_step(m, bs, 4096).total_ns() / p3);
                r_ecco.push(Accel::ecco().decode_step(m, bs, 4096).total_ns() / p3);
            }
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (a, b, c) = (avg(&r_npu), avg(&r_hbm), avg(&r_ecco));
        assert!((4.0..18.0).contains(&a), "NPU ratio {a}");
        assert!((2.5..8.0).contains(&b), "HBM-PIM ratio {b}");
        assert!((1.2..3.5).contains(&c), "Ecco ratio {c}");
    }

    #[test]
    fn pimba_enhanced_beats_orig() {
        let m = &LLAMA31_8B;
        let orig = Accel::pimba_orig().decode_step(m, 2, 4096).total_ns();
        let enh = Accel::pimba_enhanced().decode_step(m, 2, 4096).total_ns();
        let p3 = Accel::p3llm().decode_step(m, 2, 4096).total_ns();
        assert!(enh < orig);
        assert!(p3 < enh);
    }

    #[test]
    fn energy_ordering_fig10() {
        let m = &LLAMA31_8B;
        let npu = Accel::npu_fp16().decode_step(m, 2, 4096).total_pj();
        let hbm = Accel::hbm_pim().decode_step(m, 2, 4096).total_pj();
        let p3 = Accel::p3llm().decode_step(m, 2, 4096).total_pj();
        assert!(p3 < hbm && p3 < npu);
    }

    #[test]
    fn prerope_model_keeps_qk_on_npu() {
        // Llama-2 (pre-RoPE): fig 11's reduced long-context gain
        let a = Accel::p3llm();
        assert!(!a.pim_eligible(&LLAMA2_7B, "qk", Operand::KeyCache));
        assert!(a.pim_eligible(&LLAMA31_8B, "qk", Operand::KeyCache));
    }
}
